#!/usr/bin/env bash
# Repo check gate: release build + tests + lints + formatting. Run from anywhere.
set -euo pipefail

repo_root="$(cd "$(dirname "${BASH_SOURCE[0]}")/.." && pwd)"

# The cargo workspace lives wherever Cargo.toml is (repo root or rust/).
if [[ -f "$repo_root/Cargo.toml" ]]; then
  cd "$repo_root"
elif [[ -f "$repo_root/rust/Cargo.toml" ]]; then
  cd "$repo_root/rust"
else
  echo "error: no Cargo.toml under $repo_root or $repo_root/rust" >&2
  exit 1
fi

cargo build --release
cargo test -q
# Offline static-analysis gate: manifest contract on the committed golden
# fixtures (+ any freshly emitted artifacts/), BENCH_runtime.json schema
# drift against EXPERIMENTS.md (both directions), and the source lint
# (bench-write/thread-spawn confinement, coordinator unwraps, SAFETY
# comments). Exits non-zero on any finding.
cargo run --release --quiet -- analyze
# Data-parallel host smoke: two replicas over the tiny bundle must finish a
# short run through the deterministic reduce path. Needs compiled artifacts
# (`make artifacts`), so it skips politely on a bare toolchain — the
# dp-vs-single bit-identity itself is pinned by the integration tests.
if [[ -d "artifacts/rom-tiny" || -d "../artifacts/rom-tiny" ]]; then
  ROM_SKIP_EVAL=1 cargo run --release --quiet -- \
    train rom-tiny --steps 2 --dp 2
else
  echo "note: artifacts/rom-tiny absent; skipping --dp 2 train smoke" >&2
fi
# Full-attention decode smoke: the hybrid (mamba + swa + full-attn) layout
# must train a couple of steps, checkpoint, and decode through the capped
# KV-cache lane end to end — `rom generate` on a window:0 layout exercises
# prefill cache extraction, the pos-indexed decode_step scatter, and the
# host-side kv_cap guard. Artifact-gated like the dp smoke; the cross-layout
# decode parity itself is pinned by the integration tests.
if [[ -d "artifacts/hybrid" || -d "../artifacts/hybrid" ]]; then
  smoke_dir="$(mktemp -d)"
  trap 'rm -rf "$smoke_dir"' EXIT
  ROM_SKIP_EVAL=1 cargo run --release --quiet -- \
    train hybrid --steps 2 --ckpt-dir "$smoke_dir"
  cargo run --release --quiet -- \
    generate hybrid --ckpt "$smoke_dir/hybrid-step2.ckpt" \
    --prompt-tokens '17,3,250,9;101,7,33,90' --max-new 8
else
  echo "note: artifacts/hybrid absent; skipping full-attention generate smoke" >&2
fi
# Lint gate covers every target (lib, bin, benches, tests, examples); any
# warning is an error. Skips gracefully where the clippy component is absent.
if cargo clippy --version >/dev/null 2>&1; then
  cargo clippy --all-targets -- -D warnings
else
  echo "warning: cargo clippy unavailable; skipping lint gate" >&2
fi
# Rustdoc gate: broken intra-doc links, unclosed HTML-looking tags and every
# other rustdoc warning are errors (docs are a first-class deliverable).
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet
cargo fmt --check
