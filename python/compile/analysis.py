"""Analytic parameter / FLOPS accounting (Table 1 columns).

Counts are derived from the *parameter pytree shapes* plus routing facts:
  * total params  = every leaf element.
  * active params = banks (rank-3 leaves that are not routers) count only
    top_k of their E experts; everything else counts fully. This matches the
    paper's "active parameters are those used during inference".
  * fwd FLOPS/token = 2 * active matmul params + scan/conv/attention terms.

The same formulas are mirrored in rust/src/analysis/flops.rs; the python test
suite pins a few golden values that the rust proptest suite re-checks, keeping
the two implementations in lockstep.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from compile.config import ModelConfig
from compile.layers.gdn import in_proj_width as gdn_in_width
from compile.layers.mamba2 import in_proj_width as m2_in_width
from compile.train import make_init_fn


def param_counts(cfg: ModelConfig) -> Tuple[int, int]:
    """(total, active) parameter counts from the abstract pytree."""
    shapes = jax.eval_shape(make_init_fn(cfg), jnp.zeros((), jnp.int32))
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    total = 0
    active = 0
    for path, leaf in flat:
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
        keys = [getattr(p, "key", getattr(p, "idx", None)) for p in path]
        is_router = "router" in keys
        if leaf.ndim == 3 and not is_router and leaf.shape[0] > 1:
            # Expert bank: only top_k experts are active per token.
            E = leaf.shape[0]
            k = _bank_topk(cfg, keys)
            active += (n // E) * k
        else:
            active += n
    return total, active


def _bank_topk(cfg: ModelConfig, keys) -> int:
    if "w_up" in keys or "w_down" in keys or ("w_gate" in keys and "blocks" in keys
                                              and _is_mlp_key(keys)):
        return cfg.ffn_moe.top_k
    if any(k in keys for k in ("w_q", "w_k", "w_v", "w_o")):
        return 1
    return cfg.rom.top_k


def _is_mlp_key(keys) -> bool:
    # mlp blocks are the only ones with w_up; w_gate appears in both mamba and
    # mlp blocks but bank top_k is the same (1) in all experiments, so this
    # only needs to be approximately right for exotic configs.
    return "w_up" in keys


def flops_per_token(cfg: ModelConfig, seq_len: int) -> float:
    """Analytic forward FLOPS per token (multiply-accumulate = 2 FLOPs)."""
    D, Di, N, R = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.dt_rank
    K = cfg.rom.top_k if cfg.rom.enabled else 1
    fl = 0.0
    for kind in cfg.block_layout():
        if kind == "mamba":
            fl += 2 * K * (D * Di) * 2          # conv + gate banks
            fl += 2 * K * (Di * D)              # out bank
            fl += 2 * (Di * (R + 2 * N) + R * Di)  # x/dt projections (shared)
            fl += 2 * cfg.conv_kernel * Di      # depthwise conv
            fl += 10 * Di * N                   # discretize + scan + readout
            if cfg.rom.enabled and cfg.rom_targets:
                nr = 1 if cfg.routing == "shared" else len(cfg.rom_targets)
                fl += 2 * nr * D * cfg.rom.num_experts
        elif kind == "mamba2":
            fl += 2 * K * D * m2_in_width(cfg) + 2 * K * Di * D
            fl += 2 * cfg.conv_kernel * Di + 10 * Di * N
            if cfg.rom.enabled:
                fl += 2 * D * cfg.rom.num_experts
        elif kind == "gdn":
            fl += 2 * K * D * gdn_in_width(cfg) + 2 * K * Di * D
            fl += 2 * cfg.conv_kernel * Di
            fl += 8 * Di * (Di // cfg.n_heads)  # delta-rule state update/read
            if cfg.rom.enabled:
                fl += 2 * D * cfg.rom.num_experts
        elif kind == "swa":
            fl += 2 * 4 * D * D                 # q,k,v,o (active = 1 expert)
            t_eff = min(seq_len, cfg.window) if cfg.window else seq_len
            fl += 2 * 2 * D * t_eff             # qk^T and att*v
            if cfg.attn_moe != "none":
                fl += 2 * D * cfg.attn_moe_experts
        elif kind == "mlp":
            Ke = cfg.ffn_moe.top_k if cfg.ffn_moe.enabled else 1
            fl += 2 * Ke * 3 * D * (cfg.mlp_mult * D)
            if cfg.ffn_moe.enabled and not cfg.ffn_moe_share_router:
                fl += 2 * D * cfg.ffn_moe.num_experts
    fl += 2 * D * cfg.vocab_size                # lm head (tied or not)
    return fl


def describe(cfg: ModelConfig, seq_len: int) -> Dict:
    total, active = param_counts(cfg)
    return {
        "total_params": total,
        "active_params": active,
        "fwd_flops_per_token": flops_per_token(cfg, seq_len),
        "fwd_flops_seq": flops_per_token(cfg, seq_len) * seq_len,
    }
