"""Pure-jnp oracles for every Pallas kernel in this package.

These are the CORE correctness signal: each kernel's pytest sweeps shapes and
dtypes (hypothesis) and asserts allclose against the function here. They are
also usable as drop-in implementations in the L2 model (`scan_impl="loop"`,
`moe_impl="onehot"`), which is how the dense==RoM(E=1) equivalence tests close
the loop.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------------
# Selective scan (Mamba, Eq. 4-5 of the paper)
# --------------------------------------------------------------------------

def selective_scan_ref(u, dt, A, B, C, D):
    """Sequential reference for the Mamba selective scan.

    Args:
      u:  (B, T, Di)  post-conv activations.
      dt: (B, T, Di)  positive timestep (already softplus'ed).
      A:  (Di, N)     negative-real state matrix (already -exp(A_log)).
      B:  (B, T, N)   input projection (data dependent).
      C:  (B, T, N)   output projection (data dependent).
      D:  (Di,)       skip connection.
    Returns:
      y: (B, T, Di)
    """
    dA = jnp.exp(dt[..., None] * A)                     # (B,T,Di,N)
    dBu = dt[..., None] * B[:, :, None, :] * u[..., None]  # (B,T,Di,N)

    def step(h, inp):
        dA_t, dBu_t, C_t = inp
        h = dA_t * h + dBu_t                            # (B,Di,N)
        y = jnp.einsum("bdn,bn->bd", h, C_t)            # (B,Di)
        return h, y

    Bsz, _T, Di = u.shape
    N = A.shape[1]
    h0 = jnp.zeros((Bsz, Di, N), dtype=u.dtype)
    xs = (
        jnp.moveaxis(dA, 1, 0),
        jnp.moveaxis(dBu, 1, 0),
        jnp.moveaxis(C, 1, 0),
    )
    _, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1)                          # (B,T,Di)
    return y + u * D


def selective_scan_assoc(u, dt, A, B, C, D, chunk: int = 64):
    """Chunked associative-scan implementation (the fast L2 default)."""
    y, _h = selective_scan_assoc_carry(u, dt, A, B, C, D, chunk)
    return y


def selective_scan_assoc_carry(u, dt, A, B, C, D, chunk: int = 64):
    """Chunked associative scan that also returns the final recurrent state.

    Within a chunk the linear recurrence h_t = a_t h_{t-1} + b_t is solved with
    an associative scan; chunk carries are propagated sequentially with
    lax.scan, bounding peak memory at (B, chunk, Di, N). The final lax.scan
    carry IS the post-sequence state h_T — the chunk-parallel prefill extracts
    it to seed `decode_step`.

    Returns:
      (y (B, T, Di), h_final (B, Di, N))
    """
    Bsz, T, Di = u.shape
    N = A.shape[1]
    if T % chunk != 0:
        chunk = T  # degenerate: single chunk
    n_chunks = T // chunk

    dA = jnp.exp(dt[..., None] * A)                     # (B,T,Di,N)
    dBu = dt[..., None] * B[:, :, None, :] * u[..., None]

    dA_c = dA.reshape(Bsz, n_chunks, chunk, Di, N)
    dBu_c = dBu.reshape(Bsz, n_chunks, chunk, Di, N)
    C_c = C.reshape(Bsz, n_chunks, chunk, N)

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a2 * a1, a2 * b1 + b2

    def chunk_step(h, inp):
        a, bu, c = inp                                  # (B,chunk,Di,N) x2, (B,chunk,N)
        aa, bb = jax.lax.associative_scan(combine, (a, bu), axis=1)
        h_all = aa * h[:, None] + bb                    # (B,chunk,Di,N)
        y = jnp.einsum("bcdn,bcn->bcd", h_all, c)
        return h_all[:, -1], y

    h0 = jnp.zeros((Bsz, Di, N), dtype=u.dtype)
    xs = (
        jnp.moveaxis(dA_c, 1, 0),
        jnp.moveaxis(dBu_c, 1, 0),
        jnp.moveaxis(C_c, 1, 0),
    )
    h_final, ys = jax.lax.scan(chunk_step, h0, xs)      # ys: (n_chunks,B,chunk,Di)
    y = jnp.moveaxis(ys, 0, 1).reshape(Bsz, T, Di)
    return y + u * D, h_final


# --------------------------------------------------------------------------
# Grouped expert GEMM (the RoM hot-spot; megablocks analogue)
# --------------------------------------------------------------------------

def grouped_gemm_ref(x, w, route):
    """y[t] = x[t] @ w[route[t]] via a dense one-hot einsum.

    Args:
      x:     (T, D)
      w:     (E, D, F)
      route: (T,) int32 in [0, E)
    Returns:
      y: (T, F)
    """
    E = w.shape[0]
    onehot = jax.nn.one_hot(route, E, dtype=x.dtype)    # (T, E)
    return jnp.einsum("te,td,edf->tf", onehot, x, w)


# --------------------------------------------------------------------------
# Short convolution (paper Eq. 2)
# --------------------------------------------------------------------------

def short_conv_ref(x, w):
    """Depthwise causal conv (k taps) + SiLU — the paper's SC operator.

    Args:
      x: (B, T, D)
      w: (k, D) depthwise taps, tap 0 is the oldest.
    Returns:
      (B, T, D)
    """
    k = w.shape[0]
    acc = jnp.zeros_like(x)
    for i in range(k):
        shift = k - 1 - i
        xi = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, : x.shape[1]]
        acc = acc + xi * w[i]
    return jax.nn.silu(acc)
