"""Pallas depthwise causal short convolution + SiLU (paper Eq. 2).

The SC operator smooths the post-projection signal with a k=4 depthwise
causal conv followed by SiLU. On TPU this is a VPU (not MXU) kernel: each
batch row's (T, Di) tile is held in VMEM and the k taps are applied as
shifted multiply-accumulates — no im2col materialization.
interpret=True only on this image.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _conv_kernel(x_ref, w_ref, o_ref, *, k: int):
    """Grid: (B,). One batch row (T, Di) resident in VMEM."""
    x = x_ref[0]                                        # (T, Di)
    T = x.shape[0]
    acc = jnp.zeros_like(x)
    for i in range(k):                                  # k is tiny and static
        shift = k - 1 - i
        rolled = jnp.roll(x, shift, axis=0)
        mask = (jnp.arange(T) >= shift)[:, None].astype(x.dtype)
        acc = acc + rolled * mask * w_ref[i]
    o_ref[0] = jax.nn.silu(acc).astype(o_ref.dtype)


def short_conv(x, w, *, interpret: bool = True):
    """Same contract as ref.short_conv_ref: x (B,T,Di), w (k,Di) -> (B,T,Di).

    Differentiable: forward runs the Pallas kernel, backward re-derives
    cotangents through the jnp reference (shift-MAC has no in-kernel
    reverse-mode rule)."""
    return _short_conv(x, w, interpret)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _short_conv(x, w, interpret):
    return _conv_fwd_only(x, w, interpret)


def _conv_vjp_fwd(x, w, interpret):
    return _conv_fwd_only(x, w, interpret), (x, w)


def _conv_vjp_bwd(interpret, res, dy):
    from compile.kernels import ref

    x, w = res
    _, vjp = jax.vjp(ref.short_conv_ref, x, w)
    return vjp(dy)


_short_conv.defvjp(_conv_vjp_fwd, _conv_vjp_bwd)


def _conv_fwd_only(x, w, interpret):
    Bsz, T, Di = x.shape
    k = w.shape[0]
    kernel = functools.partial(_conv_kernel, k=k)
    return pl.pallas_call(
        kernel,
        grid=(Bsz,),
        in_specs=[
            pl.BlockSpec((1, T, Di), lambda b: (b, 0, 0)),
            pl.BlockSpec((k, Di), lambda b: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, T, Di), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((Bsz, T, Di), x.dtype),
        interpret=interpret,
    )(x, w)
