"""Pallas chunked selective scan (Mamba recurrence, paper Eq. 4-5).

TPU adaptation of the CUDA selective-scan kernel: the warp-parallel scan of
the original becomes a *chunked* scan — the sequence is split into chunks
sized so the (chunk, Di, N) working set fits VMEM; within a chunk the linear
recurrence is solved with a Blelloch-style associative scan on the VPU, and
chunk carries are propagated sequentially by an in-kernel fori_loop (the TPU
grid analogue of CUDA's inter-block carry chaining).

MUST run with interpret=True on this image: real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute. The BlockSpec structure below
is still the one a real TPU build would use; VMEM/MXU estimates derived from
it live in DESIGN.md / EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _scan_kernel(dA_ref, dBu_ref, C_ref, y_ref, *, chunk: int):
    """Grid: (B,). Block: one batch row, full sequence resident in VMEM.

    For the sizes this repo targets (T<=1024, Di<=512, N=16) one batch row is
    (T, Di, N) f32 <= 32 MB in the worst ladder config and <= 4 MB for the
    defaults; a real-TPU build would add a second grid axis over Di tiles.
    """
    T = dA_ref.shape[1]

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a2 * a1, a2 * b1 + b2

    def body(c, h):
        sl = pl.dslice(c * chunk, chunk)
        a = dA_ref[0, sl]                                # (chunk, Di, N)
        bu = dBu_ref[0, sl]
        cm = C_ref[0, sl]                                # (chunk, N)
        aa, bb = jax.lax.associative_scan(combine, (a, bu), axis=0)
        h_all = aa * h[None] + bb                        # (chunk, Di, N)
        y_ref[0, sl] = jnp.einsum(
            "cdn,cn->cd", h_all, cm, preferred_element_type=jnp.float32
        ).astype(y_ref.dtype)
        return h_all[-1]

    Di, N = dA_ref.shape[2], dA_ref.shape[3]
    h0 = jnp.zeros((Di, N), dtype=jnp.float32)
    n_chunks = T // chunk
    jax.lax.fori_loop(0, n_chunks, body, h0)


def selective_scan(u, dt, A, B, C, D, *, chunk: int = 64, interpret: bool = True):
    """Pallas-backed selective scan; same contract as ref.selective_scan_ref.

    Differentiable: the forward pass runs the Pallas kernel; the backward pass
    re-derives cotangents through the chunked associative-scan reference (the
    in-kernel fori_loop has no reverse-mode rule). Numerically both paths
    compute the same recurrence, so grads match the oracle to fp32 tolerance.
    """
    return _selective_scan(u, dt, A, B, C, D, chunk, interpret)


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7))
def _selective_scan(u, dt, A, B, C, D, chunk, interpret):
    return _scan_fwd_only(u, dt, A, B, C, D, chunk, interpret)


def _scan_fwd_only(u, dt, A, B, C, D, chunk, interpret):
    """The ZOH discretization (elementwise) is done outside the kernel so XLA
    can fuse it with its producers; the kernel owns the recurrence + readout."""
    Bsz, T, Di = u.shape
    N = A.shape[1]
    if T % chunk != 0:
        chunk = T

    dA = jnp.exp(dt[..., None] * A)                      # (B,T,Di,N)
    dBu = dt[..., None] * B[:, :, None, :] * u[..., None]

    kernel = functools.partial(_scan_kernel, chunk=chunk)
    y = pl.pallas_call(
        kernel,
        grid=(Bsz,),
        in_specs=[
            pl.BlockSpec((1, T, Di, N), lambda b: (b, 0, 0, 0)),
            pl.BlockSpec((1, T, Di, N), lambda b: (b, 0, 0, 0)),
            pl.BlockSpec((1, T, N), lambda b: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, T, Di), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((Bsz, T, Di), u.dtype),
        interpret=interpret,
    )(dA, dBu, C)
    return y + u * D


def _scan_vjp_fwd(u, dt, A, B, C, D, chunk, interpret):
    y = _scan_fwd_only(u, dt, A, B, C, D, chunk, interpret)
    return y, (u, dt, A, B, C, D)


def _scan_vjp_bwd(chunk, interpret, res, dy):
    from compile.kernels import ref

    u, dt, A, B, C, D = res
    _, vjp = jax.vjp(
        lambda *args: ref.selective_scan_assoc(*args, chunk=chunk), u, dt, A, B, C, D
    )
    return vjp(dy)


_selective_scan.defvjp(_scan_vjp_fwd, _scan_vjp_bwd)
