"""Megablocks-style grouped expert GEMM in Pallas (the RoM hot-spot).

The paper accelerates its expert projections with Megablocks' grouped_GEMM
CUDA kernels. TPU re-think (DESIGN.md §Hardware-Adaptation): tokens are sorted
by expert and each expert's group padded to a multiple of the token block size
Bt, producing a dense block schedule `block_expert[b] -> e`; the kernel grid
walks token blocks, streams the (Bt, D) activation tile and the (D, F) weight
tile of that block's expert into VMEM, and issues one MXU GEMM per block.
Because RoM *shares* one routing decision across the Conv/Gate/Out banks, the
sort permutation and block schedule are identical for all three grouped GEMMs
of a Mamba block; XLA CSE collapses the three plan computations into one — the
TPU analogue of the paper's claim that shared routing amortizes router work.

Compute is proportional to #tokens + padding (<= E*Bt extra rows), unlike the
one-hot oracle which is E× dense. interpret=True only on this image (real-TPU
lowering emits Mosaic custom-calls the CPU PJRT plugin cannot run).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 16


class GroupPlan(NamedTuple):
    """Sort/pad schedule derived from one top-1 routing decision."""

    pos: jax.Array           # (T,) destination row of token t in the padded buffer
    block_expert: jax.Array  # (NB,) expert id of each token block
    padded_len: int          # static: NB * block_size
    block_size: int


def make_group_plan(route: jax.Array, num_experts: int,
                    block_size: int = DEFAULT_BLOCK) -> GroupPlan:
    """Build the megablocks schedule for a top-1 routing decision.

    Args:
      route: (T,) int32 expert assignment per token.
      num_experts: E.
      block_size: Bt, the token-block granularity (128 on a real MXU; smaller
        here so tests exercise multi-block schedules at tiny T).
    Returns:
      GroupPlan with static padded_len = round_up(T + E*Bt, Bt) (upper bound;
      trailing blocks beyond the last expert group carry only zero rows).
    """
    T = route.shape[0]
    E = num_experts
    counts = jnp.bincount(route, length=E)                       # (E,)
    padded_counts = ((counts + block_size - 1) // block_size) * block_size
    offsets = jnp.cumsum(padded_counts) - padded_counts          # exclusive
    # Rank of each token within its expert group (stable sort order).
    order = jnp.argsort(route, stable=True)                      # (T,)
    inv = jnp.argsort(order, stable=True)
    start = jnp.cumsum(counts) - counts                          # exclusive
    rank_sorted = jnp.arange(T) - start[route[order]]
    rank = rank_sorted[inv]
    pos = offsets[route] + rank                                  # (T,)

    padded_len = T + E * block_size                              # static bound
    padded_len = ((padded_len + block_size - 1) // block_size) * block_size
    nb = padded_len // block_size
    # block -> expert: block b belongs to expert e iff its first row falls in
    # [offsets[e], offsets[e] + padded_counts[e]). Trailing blocks match no
    # expert and argmax defaults them to 0; their rows are all-zero so they
    # contribute nothing in either the forward or the wgrad kernel.
    bstart = jnp.arange(nb) * block_size
    in_e = (bstart[:, None] >= offsets[None, :]) & (
        bstart[:, None] < (offsets + padded_counts)[None, :]
    )                                                            # (NB, E)
    block_expert = jnp.argmax(in_e, axis=1).astype(jnp.int32)
    return GroupPlan(pos=pos, block_expert=block_expert,
                     padded_len=padded_len, block_size=block_size)


def scatter_tokens(x: jax.Array, plan: GroupPlan) -> jax.Array:
    """(T, D) -> (T_pad, D): place token t at row plan.pos[t], zeros elsewhere."""
    out = jnp.zeros((plan.padded_len, x.shape[1]), dtype=x.dtype)
    return out.at[plan.pos].set(x)


def gather_tokens(y_pad: jax.Array, plan: GroupPlan) -> jax.Array:
    """(T_pad, F) -> (T, F): read token t back from row plan.pos[t]."""
    return y_pad[plan.pos]


def _gg_kernel(be_ref, x_ref, w_ref, o_ref):
    """Grid: (NB,). x block (Bt, D) @ w[block_expert[b]] (D, F) -> o block."""
    b = pl.program_id(0)
    e = be_ref[b]
    w = w_ref[e]                                         # (D, F) dynamic gather
    o_ref[...] = jnp.dot(
        x_ref[...], w, preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def _grouped_matmul_padded(x_pad, w, block_expert, *, block_size: int,
                           interpret: bool = True):
    """(T_pad, D) x (E, D, F) -> (T_pad, F) with per-block expert weights."""
    T_pad, D = x_pad.shape
    E, _, F = w.shape
    nb = T_pad // block_size
    return pl.pallas_call(
        _gg_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((nb,), lambda b: (0,)),          # schedule, resident
            pl.BlockSpec((block_size, D), lambda b: (b, 0)),
            pl.BlockSpec((E, D, F), lambda b: (0, 0, 0)),  # full weight bank
        ],
        out_specs=pl.BlockSpec((block_size, F), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((T_pad, F), x_pad.dtype),
        interpret=interpret,
    )(block_expert, x_pad, w)


def _wgrad_kernel(be_ref, x_ref, dy_ref, dw_ref):
    """Grid: (NB,). Accumulate x^T dy into the block's expert dW tile. The
    whole (E, D, F) output lives in VMEM across the grid (revisited block);
    it is zeroed once on the first step. A real-TPU build would tile F."""
    b = pl.program_id(0)

    @pl.when(b == 0)
    def _init():
        dw_ref[...] = jnp.zeros_like(dw_ref)

    e = be_ref[b]
    contrib = jnp.dot(
        x_ref[...].T, dy_ref[...], preferred_element_type=jnp.float32
    ).astype(dw_ref.dtype)
    dw_ref[e] = dw_ref[e] + contrib


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def grouped_gemm(x, w, route, block_size: int = DEFAULT_BLOCK,
                 interpret: bool = True):
    """y[t] = x[t] @ w[route[t]] via the megablocks schedule.

    Same contract as ref.grouped_gemm_ref, but with sparse (token-linear)
    compute. Differentiable: dgrad is a second grouped GEMM against w^T
    reusing the same plan; wgrad block-accumulates per-expert x^T dy.
    """
    y, _ = _gg_fwd(x, w, route, block_size, interpret)
    return y


def _gg_fwd(x, w, route, block_size, interpret):
    plan = make_group_plan(route, w.shape[0], block_size)
    x_pad = scatter_tokens(x, plan)
    y_pad = _grouped_matmul_padded(x_pad, w, plan.block_expert,
                                   block_size=block_size, interpret=interpret)
    y = gather_tokens(y_pad, plan)
    return y, (x_pad, w, plan)


def _gg_bwd(block_size, interpret, res, dy):
    x_pad, w, plan = res
    dy_pad = scatter_tokens(dy, plan)
    # dgrad: dx[t] = dy[t] @ w[route[t]]^T — same schedule, transposed bank.
    wT = jnp.swapaxes(w, 1, 2)
    dx_pad = _grouped_matmul_padded(dy_pad, wT, plan.block_expert,
                                    block_size=block_size, interpret=interpret)
    dx = gather_tokens(dx_pad, plan)
    # wgrad: dW[e] = sum over expert-e blocks of x_block^T dy_block.
    T_pad, D = x_pad.shape
    E, _, F = w.shape
    nb = T_pad // block_size
    dw = pl.pallas_call(
        _wgrad_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((nb,), lambda b: (0,)),
            pl.BlockSpec((block_size, D), lambda b: (b, 0)),
            pl.BlockSpec((block_size, F), lambda b: (b, 0)),
        ],
        out_specs=pl.BlockSpec((E, D, F), lambda b: (0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((E, D, F), w.dtype),
        interpret=interpret,
    )(plan.block_expert, x_pad, dy_pad)
    droute = np.zeros(dy.shape[:1], dtype=jax.dtypes.float0)
    return dx, dw, droute


grouped_gemm.defvjp(_gg_fwd, _gg_bwd)
