"""L1: Pallas kernels for the paper's compute hot-spots.

- selective_scan: the Mamba recurrence (chunked scan).
- grouped_gemm: megablocks-style sparse expert projection (the RoM hot-spot).
- short_conv: depthwise causal conv + SiLU.
- ref: pure-jnp oracles for all of the above (the correctness signal).
"""

from compile.kernels.grouped_gemm import grouped_gemm, make_group_plan  # noqa: F401
from compile.kernels.selective_scan import selective_scan  # noqa: F401
from compile.kernels.short_conv import short_conv  # noqa: F401
