"""AOT lowering: jax model -> HLO text artifacts + manifest for rust.

Interchange is HLO *text*: the image's xla_extension 0.5.1 rejects jax>=0.5
serialized HloModuleProtos (64-bit instruction ids), while the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage:
  python -m compile.aot --preset rom-e2e [--out-root ../artifacts] [--golden]
  python -m compile.aot --all
  python -m compile.aot --emit-configs ../configs

Artifacts per variant (DESIGN.md §2 artifact contract):
  init.hlo.txt, step.hlo.txt, grad.hlo.txt, apply.hlo.txt,
  eval_L{T}.hlo.txt (one per cfg.eval_lens), manifest.json,
  decode_step.hlo.txt + prefill_L{T}.hlo.txt (generation; see compile.decode
  — omitted, with the reason recorded in the manifest, when the variant
  cannot carry fixed-shape decode state)
  [+ golden.json with python-side step losses when --golden]
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import analysis, decode, train
from compile.config import ModelConfig
from compile.model import num_routers
from compile.presets import all_presets, emit_configs, get_preset


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _leaf_name(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return ".".join(parts)


def param_manifest(cfg: ModelConfig):
    shapes = jax.eval_shape(train.make_init_fn(cfg), jnp.zeros((), jnp.int32))
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    return [
        {
            "name": _leaf_name(path),
            "shape": list(leaf.shape),
            "dtype": str(leaf.dtype),
        }
        for path, leaf in flat
    ]


def lower_variant(cfg: ModelConfig, out_dir: str, golden: bool = False) -> Dict:
    os.makedirs(out_dir, exist_ok=True)
    B, T = cfg.batch_size, cfg.seq_len
    mb = cfg.micro_batch if cfg.micro_batch > 0 else max(1, B // 2)

    f32 = jnp.float32
    i32 = jnp.int32
    sd = jax.ShapeDtypeStruct
    params_sd = jax.eval_shape(train.make_init_fn(cfg), sd((), i32))

    def write(name: str, lowered):
        path = os.path.join(out_dir, name)
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        return len(text)

    sizes = {}
    # init: seed -> params
    sizes["init"] = write(
        "init.hlo.txt", jax.jit(train.make_init_fn(cfg)).lower(sd((), i32)))

    # step: fused train step
    tok = sd((B, T), i32)
    sizes["step"] = write(
        "step.hlo.txt",
        jax.jit(train.make_step_fn(cfg)).lower(
            params_sd, params_sd, params_sd, sd((), f32), sd((), f32), tok, tok))

    # grad/apply: microbatch accumulation path
    mtok = sd((mb, T), i32)
    sizes["grad"] = write(
        "grad.hlo.txt",
        jax.jit(train.make_grad_fn(cfg)).lower(params_sd, params_sd, mtok, mtok))
    sizes["apply"] = write(
        "apply.hlo.txt",
        jax.jit(train.make_apply_fn(cfg)).lower(
            params_sd, params_sd, params_sd, params_sd,
            sd((), f32), sd((), f32), sd((), f32)))

    # eval at each context length (batch 1) + final-position-only variant
    # (the cloze/LAMBADA probe primitive).
    for L in cfg.eval_lens:
        etok = sd((1, L), i32)
        sizes[f"eval_L{L}"] = write(
            f"eval_L{L}.hlo.txt",
            jax.jit(train.make_eval_fn(cfg)).lower(params_sd, etok, etok))
    L = cfg.eval_lens[0]
    etok = sd((1, L), i32)
    sizes[f"eval_last_L{L}"] = write(
        f"eval_last_L{L}.hlo.txt",
        jax.jit(train.make_eval_last_fn(cfg)).lower(params_sd, etok, etok))

    # Generation artifacts: one-token decode step + chunk-parallel prefill at
    # each eval length, with the recurrent state as an explicit flat tensor
    # list (the manifest "decode" section is the calling convention).
    decode_reason = decode.unsupported_reason(cfg)
    decode_manifest = None
    if decode_reason is None:
        Bd = cfg.decode_batch
        spec = decode.state_spec(cfg)
        state_sd = [sd(tuple(s["shape"]), jnp.dtype(s["dtype"])) for s in spec]
        sizes["decode_step"] = write(
            "decode_step.hlo.txt",
            jax.jit(decode.make_decode_step_fn(cfg)).lower(
                params_sd, sd((Bd,), i32), state_sd))
        for L in cfg.eval_lens:
            sizes[f"prefill_L{L}"] = write(
                f"prefill_L{L}.hlo.txt",
                jax.jit(decode.make_prefill_fn(cfg)).lower(
                    params_sd, sd((Bd, L), i32)))
        # kv_cap: capacity of the full-attention KV-cache lanes (window <= 0
        # swa blocks only; null for rolling-window and pure-SSM layouts). The
        # rust coordinator uses it to stop requests cleanly at cap exhaustion.
        full_attn = "swa" in cfg.block_layout() and cfg.window <= 0
        decode_manifest = {
            "batch": Bd,
            "prefill_lens": cfg.eval_lens,
            "kv_cap": cfg.kv_cap if full_attn else None,
            "state": spec,
        }

    desc = analysis.describe(cfg, T)
    leaves = param_manifest(cfg)
    manifest = {
        "name": cfg.name,
        "model": cfg.to_dict(),
        "params": leaves,
        "num_param_leaves": len(leaves),
        "batch_size": B,
        "seq_len": T,
        "micro_batch": mb,
        "eval_lens": cfg.eval_lens,
        "num_routers": num_routers(cfg),
        "num_experts": max(cfg.rom.num_experts, cfg.ffn_moe.num_experts,
                           cfg.attn_moe_experts if cfg.attn_moe != "none" else 1),
        "analysis": desc,
        "artifact_bytes": sizes,
        # Present iff generation artifacts were emitted; otherwise the
        # reason is recorded so `rom generate` can explain itself.
        "decode": decode_manifest,
        "decode_unsupported": decode_reason,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)

    if golden:
        _write_golden(cfg, out_dir)
    return manifest


def _write_golden(cfg: ModelConfig, out_dir: str, seed: int = 0, steps: int = 2):
    """Run the fused step in python and record losses for the rust cross-check."""
    B, T = cfg.batch_size, cfg.seq_len
    params = jax.jit(train.make_init_fn(cfg))(jnp.asarray(seed, jnp.int32))
    m = jax.tree_util.tree_map(jnp.zeros_like, params)
    v = jax.tree_util.tree_map(jnp.zeros_like, params)
    step_fn = jax.jit(train.make_step_fn(cfg))
    rng = np.random.RandomState(1234)
    losses = []
    for s in range(1, steps + 1):
        tokens = rng.randint(0, cfg.vocab_size, size=(B, T)).astype(np.int32)
        targets = rng.randint(0, cfg.vocab_size, size=(B, T)).astype(np.int32)
        params, m, v, loss, _ = step_fn(
            params, m, v, jnp.asarray(float(s)), jnp.asarray(4e-4),
            jnp.asarray(tokens), jnp.asarray(targets))
        losses.append(float(loss))
    with open(os.path.join(out_dir, "golden.json"), "w") as f:
        json.dump({"seed": seed, "data_seed": 1234, "lr": 4e-4,
                   "losses": losses}, f, indent=1)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--preset", action="append", default=[],
                    help="preset name (repeatable)")
    ap.add_argument("--all", action="store_true", help="lower every preset")
    ap.add_argument("--out-root", default="../artifacts")
    ap.add_argument("--golden", action="store_true",
                    help="also run 2 python steps and record golden losses")
    ap.add_argument("--emit-configs", metavar="DIR",
                    help="write configs/<name>.json for every preset and exit")
    ap.add_argument("--config", help="lower a single JSON config file")
    args = ap.parse_args()

    if args.emit_configs:
        for path in emit_configs(args.emit_configs):
            print(f"wrote {path}")
        return

    targets = []
    if args.all:
        targets = list(all_presets().values())
    for name in args.preset:
        targets.append(get_preset(name))
    if args.config:
        with open(args.config) as f:
            targets.append(ModelConfig.from_dict(json.load(f)))
    if not targets:
        ap.error("nothing to do: pass --preset, --all, --config or --emit-configs")

    for cfg in targets:
        out_dir = os.path.join(args.out_root, cfg.name)
        man = lower_variant(cfg, out_dir, golden=args.golden)
        a = man["analysis"]
        print(f"{cfg.name}: leaves={man['num_param_leaves']} "
              f"total={a['total_params']/1e6:.2f}M active={a['active_params']/1e6:.2f}M "
              f"-> {out_dir}")


if __name__ == "__main__":
    main()
