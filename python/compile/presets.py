"""Named model presets: one per experiment row (DESIGN.md §4).

The paper's scales (115M/353M/765M/1.3B, Samba 421M/511M) map onto a tiny
ladder with the same layer/width *ratios* (substitution table in DESIGN.md);
`emit_configs()` writes each preset as configs/<name>.json for the rust side.

Naming convention: <arch>-<scale>[-<moe tag>].
"""

from __future__ import annotations

import dataclasses
import os
from typing import Dict, List

from compile.config import ModelConfig, MoEConfig

# Tiny ladder mirroring Table 5 ratios (n_layers x d_model):
# paper: 115M=24x768, 353M=48x1024, 765M=48x1536, 1.3B=48x2048
# here (pure-mamba layer counts; samba uses groups of 3 blocks):
LADDER = {
    "tiny": dict(n_layers=4, d_model=64),
    "small": dict(n_layers=6, d_model=96),
    "base": dict(n_layers=6, d_model=144),
    "large": dict(n_layers=6, d_model=192),
}

ROM8 = MoEConfig(num_experts=8, top_k=1)
FFN8 = MoEConfig(num_experts=8, top_k=1)
FFN16 = MoEConfig(num_experts=16, top_k=1)


def _mk(name: str, **kw) -> ModelConfig:
    return ModelConfig(name=name, **kw)


def all_presets() -> Dict[str, ModelConfig]:
    p: Dict[str, ModelConfig] = {}

    # ---- Fig 3/4 ladder: dense Mamba vs RoM (Conv,Gate,Out shared top-1/8) --
    for scale, dims in LADDER.items():
        p[f"mamba-{scale}"] = _mk(f"mamba-{scale}", arch="mamba", **dims)
        p[f"rom-{scale}"] = _mk(
            f"rom-{scale}", arch="mamba", **dims,
            rom_targets=["conv", "gate", "out"], routing="shared",
            rom=dataclasses.replace(ROM8))

    # ---- Fig 2 / Table 4: Samba 421M analogue + naive MoE-Mamba combos -----
    samba_dims = dict(n_layers=2, d_model=96, expand=2)  # 2 groups of [mamba,swa,mlp]
    p["samba-e2"] = _mk("samba-e2", arch="samba", **samba_dims)
    combos = [("conv",), ("gate",), ("out",), ("conv", "gate"),
              ("conv", "out"), ("gate", "out"), ("conv", "gate", "out")]
    for combo in combos:
        tag = "".join(c[0] for c in combo)  # c, g, o, cg, ...
        p[f"samba-e2-moemamba-{tag}"] = _mk(
            f"samba-e2-moemamba-{tag}", arch="samba", **samba_dims,
            rom_targets=list(combo), routing="independent",
            rom=dataclasses.replace(ROM8))
    p["samba-e2-rom"] = _mk(
        "samba-e2-rom", arch="samba", **samba_dims,
        rom_targets=["conv", "gate", "out"], routing="shared",
        rom=dataclasses.replace(ROM8))

    # ---- Table 1 extras ----------------------------------------------------
    p["llama"] = _mk("llama", arch="llama", n_layers=3, d_model=96, window=0)
    # Attention+SSM hybrid with FULL attention (window=0): the Samba layout
    # serving through the capped kv_cap decode path instead of rolling SWA —
    # the paper's §hybrid configuration (RoM scaling hybrids, 23% FLOPS
    # saving) as a decodable preset.
    p["hybrid"] = _mk("hybrid", arch="samba", **samba_dims, window=0)
    p["mamba-t1"] = _mk("mamba-t1", arch="mamba", n_layers=6, d_model=96)
    p["samba-e2-moa"] = _mk("samba-e2-moa", arch="samba", **samba_dims,
                            attn_moe="moa", attn_moe_experts=8)
    p["samba-e2-switchhead"] = _mk("samba-e2-switchhead", arch="samba",
                                   **samba_dims, attn_moe="switchhead",
                                   attn_moe_experts=8)
    samba4_dims = dict(n_layers=2, d_model=96, expand=4)
    p["samba-e4"] = _mk("samba-e4", arch="samba", **samba4_dims)
    p["samba-e4-rom-go"] = _mk(
        "samba-e4-rom-go", arch="samba", **samba4_dims,
        rom_targets=["gate", "out"], routing="shared", rom=dataclasses.replace(ROM8))
    p["samba-e4-rom"] = _mk(
        "samba-e4-rom", arch="samba", **samba4_dims,
        rom_targets=["conv", "gate", "out"], routing="shared",
        rom=dataclasses.replace(ROM8))
    p["samba-e4-rom-all"] = _mk(
        "samba-e4-rom-all", arch="samba", **samba4_dims,
        rom_targets=["conv", "gate", "dt", "x", "out"], routing="shared",
        rom=dataclasses.replace(ROM8))

    # ---- Table 6: load balance ablation ------------------------------------
    p["samba-e4-rom-bal"] = _mk(
        "samba-e4-rom-bal", arch="samba", **samba4_dims,
        rom_targets=["conv", "gate", "out"], routing="shared",
        rom=MoEConfig(num_experts=8, top_k=1, balance_loss=1e-3))
    p["samba-e4-rom-all-bal"] = _mk(
        "samba-e4-rom-all-bal", arch="samba", **samba4_dims,
        rom_targets=["conv", "gate", "dt", "x", "out"], routing="shared",
        rom=MoEConfig(num_experts=8, top_k=1, balance_loss=1e-3))

    # ---- Table 3: other linear recurrent architectures + RoM ---------------
    small = LADDER["small"]
    p["mamba2-small"] = _mk("mamba2-small", arch="mamba2", **small)
    p["mamba2-small-rom"] = _mk("mamba2-small-rom", arch="mamba2", **small,
                                rom=dataclasses.replace(ROM8))
    p["gdn-small"] = _mk("gdn-small", arch="gdn", **small)
    p["gdn-small-rom"] = _mk("gdn-small-rom", arch="gdn", **small,
                             rom=dataclasses.replace(ROM8))

    # ---- Table 2 / 10: FFN-MoE vs hybrid RoM+FFN-MoE ------------------------
    p["samba-ffnmoe16"] = _mk(
        "samba-ffnmoe16", arch="samba", **samba4_dims,
        ffn_moe=dataclasses.replace(FFN16))
    p["samba-rom-ffnmoe8"] = _mk(
        "samba-rom-ffnmoe8", arch="samba", **samba4_dims,
        rom_targets=["conv", "gate", "out"], routing="shared",
        rom=dataclasses.replace(ROM8),
        ffn_moe=dataclasses.replace(FFN8), ffn_moe_share_router=True)

    # ---- e2e example model (pallas kernels on the hot path) ----------------
    p["rom-e2e"] = _mk(
        "rom-e2e", arch="mamba", n_layers=4, d_model=96,
        rom_targets=["conv", "gate", "out"], routing="shared",
        rom=dataclasses.replace(ROM8), scan_impl="pallas")

    # ---- §Perf ablation variants (EXPERIMENTS.md) ---------------------------
    # Same model as rom-tiny but with the megablocks grouped-GEMM expert path
    # (L1 kernel) instead of the one-hot einsum; and mamba-tiny with the
    # pallas scan instead of the associative-scan reference.
    p["rom-tiny-grouped"] = _mk(
        "rom-tiny-grouped", arch="mamba", **LADDER["tiny"],
        rom_targets=["conv", "gate", "out"], routing="shared",
        rom=dataclasses.replace(ROM8), moe_impl="grouped")
    p["mamba-tiny-pallas"] = _mk(
        "mamba-tiny-pallas", arch="mamba", **LADDER["tiny"], scan_impl="pallas")

    return p


def get_preset(name: str) -> ModelConfig:
    presets = all_presets()
    if name not in presets:
        raise KeyError(f"unknown preset {name!r}; have {sorted(presets)}")
    return presets[name]


def emit_configs(out_dir: str) -> List[str]:
    os.makedirs(out_dir, exist_ok=True)
    written = []
    for name, cfg in all_presets().items():
        path = os.path.join(out_dir, f"{name}.json")
        with open(path, "w") as f:
            f.write(cfg.to_json() + "\n")
        written.append(path)
    return written
