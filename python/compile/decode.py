"""Stateful autoregressive decoding: the generation-artifact builders.

The paper's efficiency claim — "constant inference-time computation and
memory complexity" — is only observable with a decode path. This module
assembles the per-block step functions (`mamba_block_step` & co.) into two
artifacts per variant, lowered by `compile.aot` next to the training ones:

  prefill_L{L} : (params, tokens (B, L) i32) -> (logits (B, V), state...)
                 consume a prompt, return last-position logits + the packed
                 recurrent state. Lowered CHUNK-PARALLEL in L: each block runs
                 its training-side forward over the whole prompt (associative
                 scans for Mamba-1/2, windowed attention for SWA, one fused
                 sequential scan for GDN) and additionally extracts the decode
                 state — the scan carries, the last k-1 conv inputs, the last
                 `window` post-RoPE K/V rows. `make_stepwise_prefill_fn` keeps
                 the old sequential lax.scan over the step body as the parity
                 reference.
  decode_step  : (params, token (B,) i32, state...) -> (logits (B, V), state...)
                 one token in, carried state in -> next-token logits, state out.

The state is an explicit flat tensor list in a fixed layout-walk order
(`state_spec`), recorded in the manifest's "decode" section so the rust
runtime can allocate, thread and validate it without rebuilding the model:

  pos                ()            i32   tokens consumed so far
  blocks.{i}.conv    (B, k-1, Di)  f32   rolling conv-input window (SSM blocks)
  blocks.{i}.ssm     (B, Di, N)    f32   Mamba selective-scan state
  blocks.{i}.ssd     (B, H, P, N)  f32   Mamba-2 SSD state
  blocks.{i}.delta   (B, H, Dk, Dk) f32  GDN delta-rule state
  blocks.{i}.k_cache (B, W, D)     f32   SWA rolling key cache (post-RoPE)
  blocks.{i}.v_cache (B, W, D)     f32   SWA rolling value cache

B is `cfg.decode_batch`. Attention caches come in two flavors sharing the
leaf names above:

  * window > 0 (SWA): rolling caches of capacity W = cfg.window, oldest
    slot first — constant memory, the Samba serving mode.
  * window <= 0 (full attention, the llama proxy and attn+SSM hybrids):
    capped position-indexed caches of capacity W = cfg.kv_cap; slot c holds
    absolute position c, written by a dynamic scatter at `pos`. The cap is
    recorded as the manifest's `decode.kv_cap` so the rust coordinator can
    refuse/stop requests that would overrun it (cap-exhaustion is a clean
    per-request stop, never a cache overwrite).

Every preset layout decodes; `unsupported_reason` is retained as the
manifest's decode/decode_unsupported XOR contract hook.
"""

from __future__ import annotations

from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from compile.config import ModelConfig
from compile.layers.attention import (attn_block_prefill,
                                      attn_block_prefill_full,
                                      attn_block_step, attn_block_step_full)
from compile.layers.gdn import gdn_block_prefill, gdn_block_step
from compile.layers.mamba2 import mamba2_block_prefill, mamba2_block_step
from compile.layers.mlp import mlp_block
from compile.layers.norm import rms_norm
from compile.layers.router import Routing
from compile.layers.ssm import mamba_block_prefill, mamba_block_step


def unsupported_reason(cfg: ModelConfig) -> Optional[str]:
    """None if the variant can decode, else a human-readable reason.

    Every current layout decodes — window <= 0 attention uses the capped
    `cfg.kv_cap` cache instead of a rolling window — so this always returns
    None today. It stays as the single gate `aot` consults (and the manifest
    decode/decode_unsupported XOR contract hangs off it) for any future
    layout that genuinely cannot carry fixed-shape state."""
    return None


def state_spec(cfg: ModelConfig) -> List[Dict]:
    """Flat state layout: [{name, shape, dtype}, ...] with batch dim
    cfg.decode_batch. Order is the artifact calling convention (leaf 0 is
    always the i32 `pos` scalar), mirrored by rust `runtime::artifact`."""
    reason = unsupported_reason(cfg)
    if reason is not None:
        raise ValueError(f"{cfg.name}: decoding unsupported ({reason})")
    B = cfg.decode_batch
    D, Di, N, k = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.conv_kernel
    H = cfg.n_heads
    spec: List[Dict] = [{"name": "pos", "shape": [], "dtype": "int32"}]

    def add(name: str, shape: List[int]):
        spec.append({"name": name, "shape": shape, "dtype": "float32"})

    for i, kind in enumerate(cfg.block_layout()):
        if kind == "mamba":
            add(f"blocks.{i}.conv", [B, k - 1, Di])
            add(f"blocks.{i}.ssm", [B, Di, N])
        elif kind == "mamba2":
            add(f"blocks.{i}.conv", [B, k - 1, Di])
            add(f"blocks.{i}.ssd", [B, H, Di // H, N])
        elif kind == "gdn":
            add(f"blocks.{i}.conv", [B, k - 1, Di])
            add(f"blocks.{i}.delta", [B, H, Di // H, Di // H])
        elif kind == "swa":
            W = cfg.window if cfg.window > 0 else cfg.kv_cap
            add(f"blocks.{i}.k_cache", [B, W, D])
            add(f"blocks.{i}.v_cache", [B, W, D])
        elif kind == "mlp":
            pass  # stateless
        else:
            raise AssertionError(kind)
    return spec


def init_state(cfg: ModelConfig, batch: Optional[int] = None) -> List[jax.Array]:
    """Zeroed state tensors matching `state_spec` (pos = 0)."""
    out: List[jax.Array] = []
    for s in state_spec(cfg):
        shape = list(s["shape"])
        if batch is not None and shape:
            shape[0] = batch
        out.append(jnp.zeros(tuple(shape), jnp.dtype(s["dtype"])))
    return out


def forward_step(cfg: ModelConfig, params: Dict, token: jax.Array,
                 state: List[jax.Array]):
    """One decode step: token (B,) i32 + state -> (logits (B, V), new state).

    Mirrors `model.forward` exactly (pre-norm residual stream, hybrid
    routing inheritance, tied/untied head); per-block math is delegated to
    the layer step functions, which are parity-tested against the
    full-window blocks.
    """
    layout = cfg.block_layout()
    pos = state[0]
    cursor = 1
    new_state: List[jax.Array] = [pos + 1]

    x = params["embed"][token]                             # (B, D)
    prev_rom_routing: Optional[Routing] = None

    for i, kind in enumerate(layout):
        p = params["blocks"][i]
        h = rms_norm(x, params["norms"][i])
        if kind == "mamba":
            out, conv, ssm, rom_r = mamba_block_step(
                cfg, p, h, state[cursor], state[cursor + 1])
            new_state += [conv, ssm]
            cursor += 2
            prev_rom_routing = rom_r if rom_r is not None else prev_rom_routing
        elif kind == "mamba2":
            out, conv, ssd, rom_r = mamba2_block_step(
                cfg, p, h, state[cursor], state[cursor + 1])
            new_state += [conv, ssd]
            cursor += 2
            prev_rom_routing = rom_r if rom_r is not None else prev_rom_routing
        elif kind == "gdn":
            out, conv, delta, rom_r = gdn_block_step(
                cfg, p, h, state[cursor], state[cursor + 1])
            new_state += [conv, delta]
            cursor += 2
            prev_rom_routing = rom_r if rom_r is not None else prev_rom_routing
        elif kind == "swa":
            step = attn_block_step if cfg.window > 0 else attn_block_step_full
            out, kc, vc = step(
                cfg, p, h, state[cursor], state[cursor + 1], pos)
            new_state += [kc, vc]
            cursor += 2
        elif kind == "mlp":
            inherited = None
            if cfg.ffn_moe.enabled and "router" not in p:
                inherited = prev_rom_routing
            out3, _ = mlp_block(cfg, p, h[:, None, :], inherited=inherited)
            out = out3[:, 0, :]
        else:
            raise AssertionError(kind)
        x = x + out

    x = rms_norm(x, params["final_norm"])
    if cfg.tie_embeddings:
        logits = x @ params["embed"].T
    else:
        logits = x @ params["lm_head"]
    return logits, new_state


def make_decode_step_fn(cfg: ModelConfig):
    def decode_step(params, token, state):
        return forward_step(cfg, params, token, state)

    return decode_step


def make_prefill_fn(cfg: ModelConfig):
    """Prompt consumption: (params, tokens (B, L)) -> (last logits, state).

    Chunk-parallel in L: mirrors `model.forward`'s block loop on the full
    prompt (pre-norm residual stream, hybrid routing inheritance, tied/untied
    head) with the `*_block_prefill` bodies, which run the training-side
    parallel forward AND extract the packed decode state. One device call,
    no per-token sequential dependency outside the scan recurrences
    themselves — this is what closed the measured 169x prefill/decode
    per-token gap (EXPERIMENTS.md §decoding).

    Parity with `make_stepwise_prefill_fn` (same state, same logits, up to
    scan-reassociation fp drift) is pinned by python/tests/test_decode.py
    for every layout at every eval_lens.
    """
    layout = cfg.block_layout()

    def prefill(params, tokens):
        B, L = tokens.shape
        x = params["embed"][tokens]                        # (B, L, D)
        state: List[jax.Array] = [jnp.asarray(L, jnp.int32)]
        prev_rom_routing: Optional[Routing] = None

        for i, kind in enumerate(layout):
            p = params["blocks"][i]
            h = rms_norm(x, params["norms"][i])
            if kind == "mamba":
                out, conv, ssm, rom_r = mamba_block_prefill(cfg, p, h)
                state += [conv, ssm]
                prev_rom_routing = rom_r if rom_r is not None else prev_rom_routing
            elif kind == "mamba2":
                out, conv, ssd, rom_r = mamba2_block_prefill(cfg, p, h)
                state += [conv, ssd]
                prev_rom_routing = rom_r if rom_r is not None else prev_rom_routing
            elif kind == "gdn":
                out, conv, delta, rom_r = gdn_block_prefill(cfg, p, h)
                state += [conv, delta]
                prev_rom_routing = rom_r if rom_r is not None else prev_rom_routing
            elif kind == "swa":
                if cfg.window > 0:
                    out, kc, vc = attn_block_prefill(cfg, p, h)
                else:
                    out, kc, vc = attn_block_prefill_full(cfg, p, h, cfg.kv_cap)
                state += [kc, vc]
            elif kind == "mlp":
                inherited = None
                if cfg.ffn_moe.enabled and "router" not in p:
                    inherited = prev_rom_routing
                out, _ = mlp_block(cfg, p, h, inherited=inherited)
            else:
                raise AssertionError(kind)
            x = x + out

        x = rms_norm(x[:, -1, :], params["final_norm"])
        if cfg.tie_embeddings:
            logits = x @ params["embed"].T
        else:
            logits = x @ params["lm_head"]
        return logits, state

    return prefill


def make_stepwise_prefill_fn(cfg: ModelConfig):
    """Sequential reference prefill: a lax.scan over the decode step body.

    Prefill + k x decode_step is consistent with L+k decode steps *by
    construction* here, which makes this the oracle the chunk-parallel
    `make_prefill_fn` is parity-tested against (it is NOT what `aot` lowers
    anymore — at L=128 it costs ~169x the per-token decode price).
    """

    def prefill(params, tokens):
        B = tokens.shape[0]
        state0 = init_state(cfg, batch=B)
        logits0 = jnp.zeros((B, cfg.vocab_size))

        def body(carry, tok_t):
            state, _ = carry
            logits, new_state = forward_step(cfg, params, tok_t, state)
            return (new_state, logits), None

        (state, logits), _ = jax.lax.scan(
            body, (state0, logits0), jnp.moveaxis(tokens, 1, 0))
        return logits, state

    return prefill
