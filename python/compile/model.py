"""L2 model assembly: the config-driven zoo (paper Fig. 5 layouts).

`init_params(cfg, key)` builds the parameter pytree; `forward(cfg, params,
tokens, key)` returns logits plus an `Aux` record (per-router expert loads,
balance loss). Parameter leaves flatten in a deterministic order (sorted dict
keys) that the AOT manifest records and the rust coordinator relies on.
"""

from __future__ import annotations

from typing import Dict, List, NamedTuple, Optional

import jax
import jax.numpy as jnp

from compile.config import ModelConfig
from compile.layers.attention import attn_block, init_attn_block
from compile.layers.gdn import gdn_block, init_gdn_block
from compile.layers.mamba2 import init_mamba2_block, mamba2_block
from compile.layers.mlp import init_mlp_block, mlp_block
from compile.layers.norm import rms_norm
from compile.layers.router import Routing
from compile.layers.ssm import init_mamba_block, mamba_block


class Aux(NamedTuple):
    load: jax.Array     # (R, E) dispatch fraction per router (R >= 1, padded)
    balance: jax.Array  # scalar aux balance loss (pre-coefficient)


def init_params(cfg: ModelConfig, key) -> Dict:
    layout = cfg.block_layout()
    keys = jax.random.split(key, len(layout) + 2)
    blocks: List[Dict] = []
    for i, kind in enumerate(layout):
        bk = keys[i]
        if kind == "mamba":
            blocks.append(init_mamba_block(cfg, bk))
        elif kind == "mamba2":
            blocks.append(init_mamba2_block(cfg, bk))
        elif kind == "gdn":
            blocks.append(init_gdn_block(cfg, bk))
        elif kind == "swa":
            blocks.append(init_attn_block(cfg, bk))
        elif kind == "mlp":
            blocks.append(init_mlp_block(cfg, bk))
        else:
            raise AssertionError(kind)
    embed = jax.random.normal(keys[-2], (cfg.vocab_size, cfg.d_model)) * 0.02
    params: Dict = {
        "embed": embed,
        "blocks": blocks,
        "norms": [jnp.ones((cfg.d_model,)) for _ in layout],
        "final_norm": jnp.ones((cfg.d_model,)),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (
            jax.random.normal(keys[-1], (cfg.d_model, cfg.vocab_size)) * 0.02)
    return params


def forward(cfg: ModelConfig, params: Dict, tokens: jax.Array,
            key: Optional[jax.Array] = None, *,
            window_override: Optional[int] = None):
    """tokens: (B, T) int32 -> (logits (B,T,V), Aux).

    `window_override` lets eval artifacts widen/narrow SWA without retracing
    configs (unused by default; SWA window is length-independent anyway).
    """
    layout = cfg.block_layout()
    B, T = tokens.shape
    x = params["embed"][tokens]                       # (B,T,D)

    all_stats: List[Routing] = []
    prev_rom_routing: Optional[Routing] = None
    window = window_override if window_override is not None else cfg.window
    if window is not None and window <= 0:
        # window <= 0 means full causal attention (the llama proxy and
        # attn+SSM hybrids); attn_block spells that `window=None`. Passing 0
        # raw would mask every score — (i>=j) & (i-j<0) is empty — degrading
        # attention to a uniform average over ALL positions, future included.
        window = None

    for i, kind in enumerate(layout):
        p = params["blocks"][i]
        h = rms_norm(x, params["norms"][i])
        bk = None if key is None else jax.random.fold_in(key, i)
        if kind == "mamba":
            out, rom_r, stats = mamba_block(cfg, p, h, bk)
            prev_rom_routing = rom_r if rom_r is not None else prev_rom_routing
        elif kind == "mamba2":
            out, rom_r, stats = mamba2_block(cfg, p, h, bk)
            prev_rom_routing = rom_r if rom_r is not None else prev_rom_routing
        elif kind == "gdn":
            out, rom_r, stats = gdn_block(cfg, p, h, bk)
            prev_rom_routing = rom_r if rom_r is not None else prev_rom_routing
        elif kind == "swa":
            out, stats = attn_block(cfg, p, h, window=window, key=bk)
        elif kind == "mlp":
            inherited = None
            if (cfg.ffn_moe.enabled and "router" not in p):
                inherited = prev_rom_routing
            out, stats = mlp_block(cfg, p, h, inherited=inherited, key=bk)
        else:
            raise AssertionError(kind)
        all_stats.extend(stats)
        x = x + out

    x = rms_norm(x, params["final_norm"])
    if cfg.tie_embeddings:
        logits = x @ params["embed"].T
    else:
        logits = x @ params["lm_head"]

    if all_stats:
        E = max(int(s.load.shape[0]) for s in all_stats)
        load = jnp.stack([
            jnp.pad(s.load, (0, E - s.load.shape[0])) for s in all_stats])
        balance = jnp.mean(jnp.stack([s.balance for s in all_stats]))
    else:
        load = jnp.zeros((1, 1))
        balance = jnp.zeros(())
    return logits, Aux(load=load, balance=balance)


def num_routers(cfg: ModelConfig) -> int:
    """How many routing decisions per forward (rows of Aux.load)."""
    n = 0
    for kind in cfg.block_layout():
        if kind == "mamba" and cfg.rom.enabled and cfg.rom_targets:
            n += 1 if cfg.routing == "shared" else len(cfg.rom_targets)
        elif kind in ("mamba2", "gdn") and cfg.rom.enabled:
            n += 1
        elif kind == "swa" and cfg.attn_moe != "none":
            n += 1
        elif kind == "mlp" and cfg.ffn_moe.enabled and not cfg.ffn_moe_share_router:
            n += 1  # hybrid inherited-routing MLPs emit no stats of their own
    return max(n, 1)
