"""The Mamba block with RoM expert projections (paper §3.1 + §4.2).

Layout of one block (paper Fig. 1):

    x ──► Conv Proj (W_in, bank) ──► ShortConv+SiLU ──► selective scan ──► Y
    x ──► Gate Proj (W_g,  bank) ──► SiLU ─────────────────────┐
                                                     Y ⊙ G ──► Out Proj (W_out, bank) ──► · R(x) ──► out
    x ──► Router W_r ── one shared decision for every bank (RoM)

Expertized banks are chosen by `cfg.rom_targets` ⊆ {conv, gate, out, dt, x};
the scan itself, the depthwise Conv1D, and (by default) the x/dt projections
stay shared across experts — the Multi-Query-Attention analogy of §4.3. Under
`routing="shared"` one decision feeds every bank and the gate weight R is
applied once after the Out projection (Eq. 12); under "independent"
(MoE-Mamba baseline) every bank routes and weighs on its own.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from compile.config import ModelConfig
from compile.layers.init import fan_in_normal
from compile.kernels import ref as kref
from compile.kernels.selective_scan import selective_scan as pallas_scan
from compile.kernels.short_conv import short_conv as pallas_conv
from compile.layers.moe_linear import bank_apply, bank_shape
from compile.layers.router import Routing, route_tokens


def _rom_E(cfg: ModelConfig, target: str) -> int:
    """Expert count for one bank: E if expertized, else 1 (dense)."""
    return cfg.rom.num_experts if target in cfg.rom_targets else 1


def init_mamba_block(cfg: ModelConfig, key) -> Dict:
    """Parameter pytree of one Mamba block (names are stable: the manifest
    and the rust checkpoint format rely on dict-key order)."""
    D, Di, N, R = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.dt_rank
    k = iter(jax.random.split(key, 12))
    init = fan_in_normal()

    def bank(target: str, din: int, dout: int):
        return init(next(k), bank_shape(_rom_E(cfg, target), din, dout))

    p = {
        "w_in": bank("conv", D, Di),
        "w_gate": bank("gate", D, Di),
        "w_out": bank("out", Di, D),
        "conv_w": init(next(k), (cfg.conv_kernel, Di)) * 0.5,
        "w_x": bank("x", Di, R + 2 * N),
        "w_dt": bank("dt", R, Di),
        "dt_bias": jnp.log(jnp.expm1(  # softplus^-1 of dt in [1e-3, 1e-1]
            jnp.exp(jax.random.uniform(next(k), (Di,),
                                       minval=jnp.log(1e-3), maxval=jnp.log(1e-1))))),
        "A_log": jnp.log(jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32), (Di, 1))),
        "D": jnp.ones((Di,)),
    }
    if cfg.rom.enabled and cfg.rom_targets:
        n_banks = len(cfg.rom_targets)
        n_routers = 1 if cfg.routing == "shared" else n_banks
        p["router"] = init(next(k), (n_routers, D, cfg.rom.num_experts))
    return p


def _routing_for(cfg: ModelConfig, p: Dict, flat_x: jax.Array, target: str,
                 key) -> Optional[Routing]:
    """Return this bank's routing decision, building it lazily per router."""
    if not (cfg.rom.enabled and target in cfg.rom_targets):
        return None
    if cfg.routing == "shared":
        idx = 0
    else:
        idx = sorted(cfg.rom_targets).index(target)
    w_r = p["router"][idx]
    return route_tokens(flat_x, w_r, cfg.rom.top_k, cfg.rom.jitter, key)


def mamba_block(cfg: ModelConfig, p: Dict, x: jax.Array,
                key=None) -> Tuple[jax.Array, Optional[Routing], list]:
    """Forward one Mamba block.

    Returns (out (B,T,D), the shared Routing (or None), list of per-router
    Routing decisions for telemetry/balance-loss — one entry per router).
    """
    B, T, D = x.shape
    Di, N, R = cfg.d_inner, cfg.d_state, cfg.dt_rank
    flat = x.reshape(B * T, D)
    use_pallas = cfg.scan_impl == "pallas"

    routings: Dict[str, Routing] = {}
    stats: list = []

    def routing(target: str) -> Optional[Routing]:
        if not (cfg.rom.enabled and target in cfg.rom_targets):
            return None
        cache_key = "shared" if cfg.routing == "shared" else target
        if cache_key not in routings:
            r = _routing_for(cfg, p, flat, target, key)
            routings[cache_key] = r
            stats.append(r)
        return routings[cache_key]

    def project(target: str, w, inp):
        """Bank projection. Shared routing uses the bare indicator here
        (Eq. 10-11); independent routing (MoE-Mamba) applies each bank's own
        gate weights immediately — standard per-layer MoE semantics."""
        r = routing(target)
        if r is not None and cfg.routing == "independent":
            return _weight_topk(inp, w, r, cfg)
        return bank_apply(inp, w, r, cfg.moe_impl)

    # Conv path (Eq. 11 with shared indicator).
    h = project("conv", p["w_in"], flat).reshape(B, T, Di)
    if use_pallas:
        u = pallas_conv(h, p["conv_w"])
    else:
        u = kref.short_conv_ref(h, p["conv_w"])

    # Data-dependent SSM parameters (shared across experts by default, §4.3).
    flat_u = u.reshape(B * T, Di)
    xdbc = project("x", p["w_x"], flat_u)                 # (BT, R+2N)
    dt_raw, Bm, Cm = jnp.split(xdbc, [R, R + N], axis=-1)
    dt = jax.nn.softplus(project("dt", p["w_dt"], dt_raw) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    scan = {"pallas": pallas_scan, "assoc": kref.selective_scan_assoc,
            "loop": kref.selective_scan_ref}[cfg.scan_impl]
    Y = scan(u, dt.reshape(B, T, Di), A,
             Bm.reshape(B, T, N), Cm.reshape(B, T, N), p["D"])

    # Gate path (Eq. 10).
    G = jax.nn.silu(project("gate", p["w_gate"], flat))   # (BT, Di)

    # Out projection on Y ⊙ G (Eq. 13), then the shared gate weight R (Eq. 12).
    inner = Y.reshape(B * T, Di) * G
    out = project("out", p["w_out"], inner)               # (BT, D)
    shared_r = routings.get("shared")
    if shared_r is not None:
        gate_w = jnp.sum(shared_r.gates, axis=-1, keepdims=True)
        out = out * gate_w
    return out.reshape(B, T, D), shared_r, stats


def _weight_topk(inp, w, r: Routing, cfg: ModelConfig):
    """Independent-routing banks weight each expert output by its own gate
    (MoE-Mamba): recompute the K partial outputs weighted. K is small."""
    acc = None
    for k in range(r.route.shape[1]):
        route_k = r.route[:, k]
        if cfg.moe_impl == "grouped":
            from compile.kernels.grouped_gemm import grouped_gemm

            yk = grouped_gemm(inp, w, route_k, 16, True)
        else:
            onehot = jax.nn.one_hot(route_k, w.shape[0], dtype=inp.dtype)
            yk = jnp.einsum("te,td,edf->tf", onehot, inp, w)
        yk = yk * r.gates[:, k][:, None]
        acc = yk if acc is None else acc + yk
    return acc


# --------------------------------------------------------------------------
# Stateful single-token decoding (autoregressive generation)
# --------------------------------------------------------------------------

def mamba_block_prefill(cfg: ModelConfig, p: Dict, x: jax.Array):
    """Parallel-in-T forward of `mamba_block` that also extracts decode state.

    This is the chunk-parallel prefill body: the same math as the training
    forward (chunked associative scan, no jitter), plus the two state tensors
    a subsequent `mamba_block_step` needs — the last k-1 conv-path inputs and
    the final selective-scan state, which the associative scan already carries.

    Args:
      x: (B, T, D) token representations, positions 0..T-1.
    Returns:
      (out (B, T, D), conv_state (B, k-1, Di), ssm_state (B, Di, N),
       shared Routing or None).
    """
    B, T, D = x.shape
    Di, N, R = cfg.d_inner, cfg.d_state, cfg.dt_rank
    k = cfg.conv_kernel
    flat = x.reshape(B * T, D)

    routings: Dict[str, Routing] = {}

    def routing(target: str) -> Optional[Routing]:
        if not (cfg.rom.enabled and target in cfg.rom_targets):
            return None
        cache_key = "shared" if cfg.routing == "shared" else target
        if cache_key not in routings:
            routings[cache_key] = _routing_for(cfg, p, flat, target, None)
        return routings[cache_key]

    def project(target: str, w, inp):
        r = routing(target)
        if r is not None and cfg.routing == "independent":
            return _weight_topk_step(inp, w, r)
        return bank_apply(inp, w, r)

    # Conv path; the rolling window state is the last k-1 pre-conv inputs
    # (zero left-pad when the prompt is shorter than the kernel).
    h = project("conv", p["w_in"], flat).reshape(B, T, Di)
    conv_state = jnp.pad(h, ((0, 0), (k - 1, 0), (0, 0)))[:, T:, :]
    u = kref.short_conv_ref(h, p["conv_w"])

    flat_u = u.reshape(B * T, Di)
    xdbc = project("x", p["w_x"], flat_u)                 # (BT, R+2N)
    dt_raw, Bm, Cm = jnp.split(xdbc, [R, R + N], axis=-1)
    dt = jax.nn.softplus(project("dt", p["w_dt"], dt_raw) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    Y, ssm_state = kref.selective_scan_assoc_carry(
        u, dt.reshape(B, T, Di), A,
        Bm.reshape(B, T, N), Cm.reshape(B, T, N), p["D"])

    G = jax.nn.silu(project("gate", p["w_gate"], flat))   # (BT, Di)
    out = project("out", p["w_out"], Y.reshape(B * T, Di) * G)
    shared_r = routings.get("shared")
    if shared_r is not None:
        out = out * jnp.sum(shared_r.gates, axis=-1, keepdims=True)
    return out.reshape(B, T, D), conv_state, ssm_state, shared_r


def conv_step(window: jax.Array, w: jax.Array) -> jax.Array:
    """One step of the depthwise causal SC operator on a (B, k, Di) window
    (oldest tap first) — the stateful analogue of `short_conv_ref`."""
    return jax.nn.silu(jnp.einsum("bkd,kd->bd", window, w))


def _weight_topk_step(inp, w, r: Routing):
    """Decode-path analogue of `_weight_topk`. Decode batches are tiny, so
    the one-hot einsum is always the right impl (the grouped GEMM is a
    training-shape optimization)."""
    acc = None
    for k in range(r.route.shape[1]):
        onehot = jax.nn.one_hot(r.route[:, k], w.shape[0], dtype=inp.dtype)
        yk = jnp.einsum("te,td,edf->tf", onehot, inp, w)
        yk = yk * r.gates[:, k][:, None]
        acc = yk if acc is None else acc + yk
    return acc


def mamba_block_step(cfg: ModelConfig, p: Dict, x: jax.Array,
                     conv_state: jax.Array, ssm_state: jax.Array):
    """One-token forward of `mamba_block`.

    Args:
      x: (B, D) the incoming token representations.
      conv_state: (B, k-1, Di) previous conv-path inputs, oldest first.
      ssm_state: (B, Di, N) selective-scan recurrent state h.
    Returns:
      (out (B, D), new_conv_state, new_ssm_state, shared Routing or None).

    The recurrence is the exact `selective_scan_ref` step; routing matches
    the full-window path with no jitter (decode is inference-only).
    """
    Di, N, R = cfg.d_inner, cfg.d_state, cfg.dt_rank

    routings: Dict[str, Routing] = {}

    def routing(target: str) -> Optional[Routing]:
        if not (cfg.rom.enabled and target in cfg.rom_targets):
            return None
        cache_key = "shared" if cfg.routing == "shared" else target
        if cache_key not in routings:
            routings[cache_key] = _routing_for(cfg, p, x, target, None)
        return routings[cache_key]

    def project(target: str, w, inp):
        r = routing(target)
        if r is not None and cfg.routing == "independent":
            return _weight_topk_step(inp, w, r)
        return bank_apply(inp, w, r)

    # Conv path: append this token's projection to the rolling window.
    h = project("conv", p["w_in"], x)                      # (B, Di)
    window = jnp.concatenate([conv_state, h[:, None, :]], axis=1)
    u = conv_step(window, p["conv_w"])

    xdbc = project("x", p["w_x"], u)                       # (B, R+2N)
    dt_raw, Bm, Cm = jnp.split(xdbc, [R, R + N], axis=-1)
    dt = jax.nn.softplus(project("dt", p["w_dt"], dt_raw) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    dA = jnp.exp(dt[..., None] * A)                        # (B, Di, N)
    dBu = dt[..., None] * Bm[:, None, :] * u[..., None]
    h_new = dA * ssm_state + dBu
    y = jnp.einsum("bdn,bn->bd", h_new, Cm) + u * p["D"]

    G = jax.nn.silu(project("gate", p["w_gate"], x))
    out = project("out", p["w_out"], y * G)
    shared_r = routings.get("shared")
    if shared_r is not None:
        out = out * jnp.sum(shared_r.gates, axis=-1, keepdims=True)
    return out, window[:, 1:, :], h_new, shared_r
