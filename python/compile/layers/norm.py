"""RMSNorm (pre-norm convention, paper App. A.2 / [49])."""

from __future__ import annotations

import jax.numpy as jnp


def rms_norm(x, gamma, eps: float = 1e-5):
    """x: (..., D), gamma: (D,)."""
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x / jnp.sqrt(ms + eps) * gamma
