"""Weight init used across the zoo.

Plain-normal fan-in scaling (LeCun variance) instead of jax's default
truncated normal: truncated sampling lowers to an `erf` HLO op that the
image's XLA 0.5.1 text parser rejects (unknown opcode). Plain normal keeps
the artifact path clean and is statistically equivalent at these scales.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def fan_in_normal():
    def init(key, shape, dtype=jnp.float32):
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
        scale = 1.0 / jnp.sqrt(jnp.asarray(fan_in, dtype))
        return jax.random.normal(key, shape, dtype) * scale

    return init
