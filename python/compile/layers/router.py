"""RoM routing (paper Eq. 7-9) and the shared-routing decision object.

A `Routing` captures one router's decision for a batch of tokens: the top-K
expert indices, the gating weights R_i(X_t) (Eq. 9: softmax probability masked
by the top-K indicator — NOT renormalized, so the router receives gradient
through the probability of the selected expert, Switch-Transformer style; this
is the straight-through stand-in for SparseMixer documented in DESIGN.md),
and per-expert load statistics for telemetry / the optional balance loss
(Eq. 16).

RoM's key idea is that ONE `Routing` is computed per Mamba block and *shared*
by every expertized projection bank (Conv/Gate/Out/...). The MoE-Mamba
baseline instead builds an independent `Routing` per bank.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


def _topk(probs: jax.Array, k: int):
    """Iterative top-k by repeated argmax. jax.lax.top_k lowers to an HLO
    `topk` custom op the image's XLA 0.5.1 parser rejects; K here is 1 or 2,
    so K argmax reductions are both compatible and cheap."""
    remaining = probs
    gates, idxs = [], []
    for _ in range(k):
        idx = jnp.argmax(remaining, axis=-1)             # (T,)
        gate = jnp.take_along_axis(probs, idx[:, None], axis=-1)[:, 0]
        gates.append(gate)
        idxs.append(idx)
        remaining = remaining.at[jnp.arange(probs.shape[0]), idx].set(-jnp.inf)
    return jnp.stack(gates, axis=-1), jnp.stack(idxs, axis=-1)


class Routing(NamedTuple):
    route: jax.Array      # (T, K) int32 selected expert ids
    gates: jax.Array      # (T, K) f32 gating weights R_i (prob * indicator)
    load: jax.Array       # (E,) fraction of tokens whose top-1 is expert e
    balance: jax.Array    # scalar: N * sum_e f_e * mean_p_e (Eq. 16 term)

    @property
    def top1(self) -> jax.Array:
        return self.route[:, 0]


def route_tokens(x: jax.Array, w_r: jax.Array, top_k: int = 1,
                 jitter: float = 0.0,
                 key: Optional[jax.Array] = None) -> Routing:
    """Compute one routing decision (paper Eq. 9).

    Args:
      x:   (T, D) token representations X_t.
      w_r: (D, E) router weights W_r.
      top_k: K.
      jitter: multiplicative input jitter amplitude (train-time exploration,
        Appendix A.3); 0 disables.
      key: PRNG key, required when jitter > 0.
    """
    if jitter > 0.0 and key is not None:
        noise = jax.random.uniform(key, x.shape, x.dtype,
                                   1.0 - jitter, 1.0 + jitter)
        x = x * noise
    logits = x @ w_r                                     # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, route = _topk(probs, top_k)                   # (T, K) each
    E = w_r.shape[1]
    # Load stats from the top-1 choice (the paper's E top-1 configs).
    onehot = jax.nn.one_hot(route[:, 0], E, dtype=x.dtype)
    f = jnp.mean(onehot, axis=0)                         # (E,) dispatch fraction
    p = jnp.mean(probs, axis=0)                          # (E,) mean router prob
    balance = E * jnp.sum(f * jax.lax.stop_gradient(p) * 0 + f * p)
    return Routing(route=route.astype(jnp.int32), gates=gates,
                   load=f, balance=balance)


def combine_topk(outputs_fn, routing: Routing, weighted: bool):
    """Sum expert outputs over the K selected experts.

    outputs_fn(route_1d) -> (T, F): output of running every token through its
    assigned expert for one of the K slots. `weighted` applies the gate weight
    R_i (used at the Out projection per Eq. 12); unweighted banks (Conv/Gate,
    Eq. 10-11) use the bare indicator.
    """
    T, K = routing.route.shape
    acc = None
    for k in range(K):
        y = outputs_fn(routing.route[:, k])
        if weighted:
            y = y * routing.gates[:, k][:, None]
        acc = y if acc is None else acc + y
    return acc
