"""Gated DeltaNet block [47] with RoM (Table 3).

Delta-rule recurrence with a per-token scalar forget gate, multi-head:

    S_t = alpha_t * (S_{t-1} - beta_t (S_{t-1} k_t - v_t) k_t^T)
        = alpha_t * (S_{t-1} (I - beta_t k_t k_t^T) + beta_t v_t k_t^T)
    y_t = S_t q_t

The delta rule is not associative in this simple form, so the scan is a
sequential lax.scan over T (CPU-friendly at this repo's scales; a WY-chunked
version is the known TPU optimization and is out of scope — Table 3 only
needs the architecture's quality shape).

RoM (comprehensive expertization, §5.4): the combined qkv/gate in-projection
and the out-projection are banks under one shared router.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from compile.config import ModelConfig
from compile.layers.init import fan_in_normal
from compile.kernels import ref as kref
from compile.layers.moe_linear import bank_apply, bank_shape
from compile.layers.router import Routing, route_tokens


def _dims(cfg: ModelConfig):
    Di = cfg.d_inner
    H = cfg.n_heads
    Dk = Di // H
    return Di, H, Dk


def in_proj_width(cfg: ModelConfig) -> int:
    Di, H, Dk = _dims(cfg)
    return 3 * Di + Di + 2 * H  # q, k, v, gate, alpha, beta


def init_gdn_block(cfg: ModelConfig, key) -> Dict:
    D = cfg.d_model
    Di, H, Dk = _dims(cfg)
    E = cfg.rom.num_experts if cfg.rom.enabled else 1
    k = iter(jax.random.split(key, 5))
    init = fan_in_normal()
    p = {
        "w_in": init(next(k), bank_shape(E, D, in_proj_width(cfg))),
        "w_out": init(next(k), bank_shape(E, Di, D)),
        "conv_w": init(next(k), (cfg.conv_kernel, Di)) * 0.5,
        "norm_g": jnp.ones((Di,)),
    }
    if cfg.rom.enabled:
        p["router"] = init(next(k), (D, E))
    return p


def _delta_scan(q, k, v, alpha, beta):
    """q/k/v: (B,T,H,Dk), alpha/beta: (B,T,H) -> y: (B,T,H,Dk)."""
    y, _S = _delta_scan_carry(q, k, v, alpha, beta)
    return y


def _delta_scan_carry(q, k, v, alpha, beta):
    """`_delta_scan` that also returns the final state S_T (B,H,Dk,Dk).

    The delta rule stays a sequential lax.scan (not associative in this form),
    but prefill still runs it ONCE over the whole prompt instead of per-token
    through the full block stack — one scan body per GDN block, everything
    around it parallel."""
    B, T, H, Dk = q.shape

    def step(S, inp):
        q_t, k_t, v_t, a_t, b_t = inp                     # (B,H,Dk)x3, (B,H)x2
        Sk = jnp.einsum("bhmn,bhn->bhm", S, k_t)          # (B,H,Dk) value-read
        delta = v_t - Sk
        S = a_t[..., None, None] * (
            S + b_t[..., None, None] * jnp.einsum("bhm,bhn->bhmn", delta, k_t))
        y = jnp.einsum("bhmn,bhn->bhm", S, q_t)
        return S, y

    S0 = jnp.zeros((B, H, Dk, Dk), dtype=q.dtype)
    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (q, k, v, alpha, beta))
    S_final, ys = jax.lax.scan(step, S0, xs)
    return jnp.moveaxis(ys, 0, 1), S_final


def gdn_block(cfg: ModelConfig, p: Dict, x: jax.Array,
              key=None) -> Tuple[jax.Array, Optional[Routing], list]:
    B, T, D = x.shape
    Di, H, Dk = _dims(cfg)
    flat = x.reshape(B * T, D)
    stats: list = []

    r: Optional[Routing] = None
    if cfg.rom.enabled:
        r = route_tokens(flat, p["router"], cfg.rom.top_k, cfg.rom.jitter, key)
        stats.append(r)

    proj = bank_apply(flat, p["w_in"], r, cfg.moe_impl)
    q, k, v, g, ab = jnp.split(proj, [Di, 2 * Di, 3 * Di, 4 * Di], axis=-1)
    alpha_raw, beta_raw = jnp.split(ab, 2, axis=-1)        # (BT,H) each

    q = kref.short_conv_ref(q.reshape(B, T, Di), p["conv_w"]).reshape(B, T, H, Dk)
    k = k.reshape(B, T, H, Dk)
    v = v.reshape(B, T, H, Dk)
    # L2-normalized keys/queries (DeltaNet convention) keep the rank-1 update stable.
    q = q / (jnp.linalg.norm(q, axis=-1, keepdims=True) + 1e-6)
    k = k / (jnp.linalg.norm(k, axis=-1, keepdims=True) + 1e-6)
    alpha = jax.nn.sigmoid(alpha_raw).reshape(B, T, H)
    beta = jax.nn.sigmoid(beta_raw).reshape(B, T, H)

    y = _delta_scan(q, k, v, alpha, beta).reshape(B * T, Di)
    y = y * jax.nn.silu(g)
    ms = jnp.mean(y * y, axis=-1, keepdims=True)
    y = y / jnp.sqrt(ms + 1e-5) * p["norm_g"]
    out = bank_apply(y, p["w_out"], r, cfg.moe_impl)
    if r is not None:
        out = out * jnp.sum(r.gates, axis=-1, keepdims=True)
    return out.reshape(B, T, D), r, stats


def gdn_block_prefill(cfg: ModelConfig, p: Dict, x: jax.Array):
    """Parallel-in-T forward of `gdn_block` that also extracts decode state.

    Everything except the (inherently sequential) delta recurrence runs
    parallel over the prompt; the recurrence itself runs once as a single
    lax.scan. Also returns the rolling q-path conv window (last k-1 pre-conv
    inputs, zero left-padded) and the final delta-rule state S.

    Args:
      x: (B, T, D) token representations, positions 0..T-1.
    Returns:
      (out (B, T, D), conv_state (B, k-1, Di), delta_state (B, H, Dk, Dk),
       Routing or None).
    """
    B, T, D = x.shape
    Di, H, Dk = _dims(cfg)
    ck = cfg.conv_kernel
    flat = x.reshape(B * T, D)

    r: Optional[Routing] = None
    if cfg.rom.enabled:
        r = route_tokens(flat, p["router"], cfg.rom.top_k)

    proj = bank_apply(flat, p["w_in"], r)
    q, k, v, g, ab = jnp.split(proj, [Di, 2 * Di, 3 * Di, 4 * Di], axis=-1)
    alpha_raw, beta_raw = jnp.split(ab, 2, axis=-1)        # (BT,H) each

    q = q.reshape(B, T, Di)
    conv_state = jnp.pad(q, ((0, 0), (ck - 1, 0), (0, 0)))[:, T:, :]
    q = kref.short_conv_ref(q, p["conv_w"]).reshape(B, T, H, Dk)
    k = k.reshape(B, T, H, Dk)
    v = v.reshape(B, T, H, Dk)
    q = q / (jnp.linalg.norm(q, axis=-1, keepdims=True) + 1e-6)
    k = k / (jnp.linalg.norm(k, axis=-1, keepdims=True) + 1e-6)
    alpha = jax.nn.sigmoid(alpha_raw).reshape(B, T, H)
    beta = jax.nn.sigmoid(beta_raw).reshape(B, T, H)

    y, delta_state = _delta_scan_carry(q, k, v, alpha, beta)
    y = y.reshape(B * T, Di)
    y = y * jax.nn.silu(g)
    ms = jnp.mean(y * y, axis=-1, keepdims=True)
    y = y / jnp.sqrt(ms + 1e-5) * p["norm_g"]
    out = bank_apply(y, p["w_out"], r)
    if r is not None:
        out = out * jnp.sum(r.gates, axis=-1, keepdims=True)
    return out.reshape(B, T, D), conv_state, delta_state, r


def gdn_block_step(cfg: ModelConfig, p: Dict, x: jax.Array,
                   conv_state: jax.Array, delta_state: jax.Array):
    """One-token forward of `gdn_block`.

    Args:
      x: (B, D) token representations.
      conv_state: (B, k-1, Di) previous q-path conv inputs, oldest first.
      delta_state: (B, H, Dk, Dk) the delta-rule state S.
    Returns:
      (out (B, D), new_conv_state, new_delta_state, Routing or None).
    """
    from compile.layers.ssm import conv_step

    B, _D = x.shape
    Di, H, Dk = _dims(cfg)

    r: Optional[Routing] = None
    if cfg.rom.enabled:
        r = route_tokens(x, p["router"], cfg.rom.top_k)

    proj = bank_apply(x, p["w_in"], r)
    q, k, v, g, ab = jnp.split(proj, [Di, 2 * Di, 3 * Di, 4 * Di], axis=-1)
    alpha_raw, beta_raw = jnp.split(ab, 2, axis=-1)        # (B, H) each

    window = jnp.concatenate([conv_state, q[:, None, :]], axis=1)
    q = conv_step(window, p["conv_w"]).reshape(B, H, Dk)
    k = k.reshape(B, H, Dk)
    v = v.reshape(B, H, Dk)
    q = q / (jnp.linalg.norm(q, axis=-1, keepdims=True) + 1e-6)
    k = k / (jnp.linalg.norm(k, axis=-1, keepdims=True) + 1e-6)
    alpha = jax.nn.sigmoid(alpha_raw)
    beta = jax.nn.sigmoid(beta_raw)

    # One step of the delta-rule recurrence (the `_delta_scan` body).
    Sk = jnp.einsum("bhmn,bhn->bhm", delta_state, k)
    delta = v - Sk
    S_new = alpha[..., None, None] * (
        delta_state + beta[..., None, None] * jnp.einsum("bhm,bhn->bhmn", delta, k))
    y = jnp.einsum("bhmn,bhn->bhm", S_new, q).reshape(B, Di)

    y = y * jax.nn.silu(g)
    ms = jnp.mean(y * y, axis=-1, keepdims=True)
    y = y / jnp.sqrt(ms + 1e-5) * p["norm_g"]
    out = bank_apply(y, p["w_out"], r)
    if r is not None:
        out = out * jnp.sum(r.gates, axis=-1, keepdims=True)
    return out, window[:, 1:, :], S_new, r
