"""Mamba-2 block (SSD, scalar-identity decay per head) with RoM.

Per §5.4 ("Comprehensive Expertization for Streamlined SSMs"), Mamba-2's
unified in/out projections are expertized *wholesale* under one shared router
when RoM is enabled: the combined in-projection (z, x, B, C, dt) and the
out-projection each become banks driven by the same decision, and the gate
weight is applied once at the output.

Recurrence (multi-head, ngroups=1):
    h_t = exp(dt_t * a_h) h_{t-1} + dt_t * x_t ⊗ B_t         h: (H, P, N)
    y_t = h_t C_t + D_h x_t
solved with the same chunked associative scan as the Mamba-1 kernel.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from compile.config import ModelConfig
from compile.layers.init import fan_in_normal
from compile.kernels import ref as kref
from compile.layers.moe_linear import bank_apply, bank_shape
from compile.layers.router import Routing, route_tokens


def _dims(cfg: ModelConfig):
    Di = cfg.d_inner
    H = cfg.n_heads
    P = Di // H
    N = cfg.d_state
    return Di, H, P, N


def in_proj_width(cfg: ModelConfig) -> int:
    Di, H, P, N = _dims(cfg)
    return 2 * Di + 2 * N + H  # z, x, B, C, dt


def init_mamba2_block(cfg: ModelConfig, key) -> Dict:
    D = cfg.d_model
    Di, H, P, N = _dims(cfg)
    E = cfg.rom.num_experts if cfg.rom.enabled else 1
    k = iter(jax.random.split(key, 6))
    init = fan_in_normal()
    p = {
        "w_in": init(next(k), bank_shape(E, D, in_proj_width(cfg))),
        "w_out": init(next(k), bank_shape(E, Di, D)),
        "conv_w": init(next(k), (cfg.conv_kernel, Di)) * 0.5,
        "A_log": jnp.log(jnp.linspace(1.0, 8.0, H)),
        "dt_bias": jnp.zeros((H,)),
        "D": jnp.ones((H,)),
        "norm_g": jnp.ones((Di,)),
    }
    if cfg.rom.enabled:
        p["router"] = init(next(k), (D, E))
    return p


def _ssd_scan(x, dt, a, B, C, chunk: int = 64):
    """x: (Bz,T,H,P), dt: (Bz,T,H), a: (H,), B/C: (Bz,T,N) -> y (Bz,T,H,P)."""
    y, _h = _ssd_scan_carry(x, dt, a, B, C, chunk)
    return y


def _ssd_scan_carry(x, dt, a, B, C, chunk: int = 64):
    """`_ssd_scan` that also returns the final state h_T (Bz,H,P,N) — the
    lax.scan chunk carry, extracted by the chunk-parallel prefill."""
    Bz, T, H, P = x.shape
    N = B.shape[-1]
    if T % chunk != 0:
        chunk = T
    n_chunks = T // chunk

    decay = jnp.exp(dt * a)                                 # (Bz,T,H)
    inc = jnp.einsum("bth,bthp,btn->bthpn", dt, x, B)       # (Bz,T,H,P,N)

    dc = decay.reshape(Bz, n_chunks, chunk, H)
    ic = inc.reshape(Bz, n_chunks, chunk, H, P, N)
    Cc = C.reshape(Bz, n_chunks, chunk, N)

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a2 * a1, a2[..., None, None] * b1 + b2

    def chunk_step(h, inp):
        d, i, c = inp                                       # (Bz,chunk,H), (Bz,chunk,H,P,N), (Bz,chunk,N)
        aa, bb = jax.lax.associative_scan(combine, (d, i), axis=1)
        h_all = aa[..., None, None] * h[:, None] + bb       # (Bz,chunk,H,P,N)
        y = jnp.einsum("bchpn,bcn->bchp", h_all, c)
        return h_all[:, -1], y

    h0 = jnp.zeros((Bz, H, P, N), dtype=x.dtype)
    xs = (jnp.moveaxis(dc, 1, 0), jnp.moveaxis(ic, 1, 0), jnp.moveaxis(Cc, 1, 0))
    h_final, ys = jax.lax.scan(chunk_step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).reshape(Bz, T, H, P), h_final


def mamba2_block(cfg: ModelConfig, p: Dict, x: jax.Array,
                 key=None) -> Tuple[jax.Array, Optional[Routing], list]:
    B, T, D = x.shape
    Di, H, P, N = _dims(cfg)
    flat = x.reshape(B * T, D)
    stats: list = []

    r: Optional[Routing] = None
    if cfg.rom.enabled:
        r = route_tokens(flat, p["router"], cfg.rom.top_k, cfg.rom.jitter, key)
        stats.append(r)

    zxbcdt = bank_apply(flat, p["w_in"], r, cfg.moe_impl)
    z, xs, Bm, Cm, dt = jnp.split(
        zxbcdt, [Di, 2 * Di, 2 * Di + N, 2 * Di + 2 * N], axis=-1)

    xs = kref.short_conv_ref(xs.reshape(B, T, Di), p["conv_w"])
    dt = jax.nn.softplus(dt + p["dt_bias"]).reshape(B, T, H)
    a = -jnp.exp(p["A_log"])

    y = _ssd_scan(xs.reshape(B, T, H, P), dt, a,
                  Bm.reshape(B, T, N), Cm.reshape(B, T, N))
    y = y + xs.reshape(B, T, H, P) * p["D"][None, None, :, None]
    y = y.reshape(B * T, Di)

    # Gated RMSNorm (Mamba-2's output norm) then out-projection.
    y = y * jax.nn.silu(z)
    ms = jnp.mean(y * y, axis=-1, keepdims=True)
    y = y / jnp.sqrt(ms + 1e-5) * p["norm_g"]
    out = bank_apply(y, p["w_out"], r, cfg.moe_impl)
    if r is not None:
        out = out * jnp.sum(r.gates, axis=-1, keepdims=True)
    return out.reshape(B, T, D), r, stats


def mamba2_block_prefill(cfg: ModelConfig, p: Dict, x: jax.Array):
    """Parallel-in-T forward of `mamba2_block` that also extracts decode state.

    Same math as the training forward (chunked SSD scan, no jitter) plus the
    rolling conv window (last k-1 pre-conv inputs, zero left-padded) and the
    final SSD state — the scan's chunk carry.

    Args:
      x: (B, T, D) token representations, positions 0..T-1.
    Returns:
      (out (B, T, D), conv_state (B, k-1, Di), ssd_state (B, H, P, N),
       Routing or None).
    """
    B, T, D = x.shape
    Di, H, P, N = _dims(cfg)
    k = cfg.conv_kernel
    flat = x.reshape(B * T, D)

    r: Optional[Routing] = None
    if cfg.rom.enabled:
        r = route_tokens(flat, p["router"], cfg.rom.top_k)

    zxbcdt = bank_apply(flat, p["w_in"], r)
    z, xs, Bm, Cm, dt = jnp.split(
        zxbcdt, [Di, 2 * Di, 2 * Di + N, 2 * Di + 2 * N], axis=-1)

    xs = xs.reshape(B, T, Di)
    conv_state = jnp.pad(xs, ((0, 0), (k - 1, 0), (0, 0)))[:, T:, :]
    xs = kref.short_conv_ref(xs, p["conv_w"])
    dt = jax.nn.softplus(dt + p["dt_bias"]).reshape(B, T, H)
    a = -jnp.exp(p["A_log"])

    y, ssd_state = _ssd_scan_carry(xs.reshape(B, T, H, P), dt, a,
                                   Bm.reshape(B, T, N), Cm.reshape(B, T, N))
    y = y + xs.reshape(B, T, H, P) * p["D"][None, None, :, None]
    y = y.reshape(B * T, Di)

    y = y * jax.nn.silu(z)
    ms = jnp.mean(y * y, axis=-1, keepdims=True)
    y = y / jnp.sqrt(ms + 1e-5) * p["norm_g"]
    out = bank_apply(y, p["w_out"], r)
    if r is not None:
        out = out * jnp.sum(r.gates, axis=-1, keepdims=True)
    return out.reshape(B, T, D), conv_state, ssd_state, r


def mamba2_block_step(cfg: ModelConfig, p: Dict, x: jax.Array,
                      conv_state: jax.Array, ssd_state: jax.Array):
    """One-token forward of `mamba2_block`.

    Args:
      x: (B, D) token representations.
      conv_state: (B, k-1, Di) previous conv inputs, oldest first.
      ssd_state: (B, H, P, N) the SSD recurrent state h.
    Returns:
      (out (B, D), new_conv_state, new_ssd_state, Routing or None).
    """
    from compile.layers.ssm import conv_step

    B, _D = x.shape
    Di, H, P, N = _dims(cfg)

    r: Optional[Routing] = None
    if cfg.rom.enabled:
        r = route_tokens(x, p["router"], cfg.rom.top_k)

    zxbcdt = bank_apply(x, p["w_in"], r)
    z, xs, Bm, Cm, dt = jnp.split(
        zxbcdt, [Di, 2 * Di, 2 * Di + N, 2 * Di + 2 * N], axis=-1)

    window = jnp.concatenate([conv_state, xs[:, None, :]], axis=1)
    xs = conv_step(window, p["conv_w"])
    dt = jax.nn.softplus(dt + p["dt_bias"])                # (B, H)
    a = -jnp.exp(p["A_log"])

    # One step of the SSD recurrence (the `_ssd_scan` body at T=1).
    decay = jnp.exp(dt * a)                                # (B, H)
    xh = xs.reshape(B, H, P)
    inc = jnp.einsum("bh,bhp,bn->bhpn", dt, xh, Bm)
    h_new = decay[..., None, None] * ssd_state + inc
    y = jnp.einsum("bhpn,bn->bhp", h_new, Cm) + xh * p["D"][None, :, None]
    y = y.reshape(B, Di)

    y = y * jax.nn.silu(z)
    ms = jnp.mean(y * y, axis=-1, keepdims=True)
    y = y / jnp.sqrt(ms + 1e-5) * p["norm_g"]
    out = bank_apply(y, p["w_out"], r)
    if r is not None:
        out = out * jnp.sum(r.gates, axis=-1, keepdims=True)
    return out, window[:, 1:, :], h_new, r
