"""L2 building blocks: routers, expert projections, SSM variants, attention,
MLPs and norms. Pure functions over parameter pytrees (no flax/haiku)."""
