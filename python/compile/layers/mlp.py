"""SwiGLU MLP and FFN-MoE (Table 2 / Table 10 baselines and hybrids).

FFN-MoE experts are whole SwiGLU networks (up/gate/down expertized together —
the "holistic expertization" finding of §5.4). The hybrid RoM+FFN-MoE variant
(App. A.2 Eq. 14-15) *reuses the routing decision of the preceding RoM layer*
instead of learning its own router — pass it as `inherited`.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from compile.config import ModelConfig
from compile.layers.init import fan_in_normal
from compile.layers.moe_linear import bank_apply, bank_shape
from compile.layers.router import Routing, route_tokens


def init_mlp_block(cfg: ModelConfig, key) -> Dict:
    D = cfg.d_model
    Dh = cfg.mlp_mult * D
    E = cfg.ffn_moe.num_experts
    k = iter(jax.random.split(key, 5))
    init = fan_in_normal()
    p = {
        "w_up": init(next(k), bank_shape(E, D, Dh)),
        "w_gate": init(next(k), bank_shape(E, D, Dh)),
        "w_down": init(next(k), bank_shape(E, Dh, D)),
    }
    if cfg.ffn_moe.enabled and not cfg.ffn_moe_share_router:
        p["router"] = init(next(k), (D, E))
    return p


def mlp_block(cfg: ModelConfig, p: Dict, x: jax.Array,
              inherited: Optional[Routing] = None,
              key=None) -> Tuple[jax.Array, list]:
    """Returns (out, router stats list). `inherited` = shared routing decision
    from the preceding RoM layer (hybrid RoM+FFN-MoE, Eq. 14-15)."""
    B, T, D = x.shape
    flat = x.reshape(B * T, D)
    stats: list = []

    r: Optional[Routing] = None
    if cfg.ffn_moe.enabled:
        if inherited is not None:
            r = inherited
        else:
            r = route_tokens(flat, p["router"], cfg.ffn_moe.top_k,
                             cfg.ffn_moe.jitter, key)
            stats.append(r)

    up = bank_apply(flat, p["w_up"], r, cfg.moe_impl)
    gate = bank_apply(flat, p["w_gate"], r, cfg.moe_impl)
    h = jax.nn.silu(gate) * up
    out = bank_apply(h, p["w_down"], r, cfg.moe_impl)
    if r is not None:
        out = out * jnp.sum(r.gates, axis=-1, keepdims=True)
    return out.reshape(B, T, D), stats
