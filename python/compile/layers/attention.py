"""Sliding-window attention (Samba's SWA), the Llama-proxy full attention,
and the attention-MoE baselines of Table 1 (MoA, SwitchHead).

MoA [50]: experts on the Query and Output projections, shared K/V — routed
per token by a dedicated router, gate-weighted at the output.
SwitchHead [5]: experts on the Value and Output projections, shared Q/K.
Both use independent routers (they predate RoM's shared-routing insight) and
are implemented with the same bank machinery as RoM so the comparison is
apples-to-apples.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from compile.config import ModelConfig
from compile.layers.init import fan_in_normal
from compile.layers.moe_linear import bank_apply, bank_shape
from compile.layers.router import Routing, route_tokens


def rope(x: jax.Array, base: float = 10000.0) -> jax.Array:
    """Rotary embedding over (B, H, T, Dh)."""
    B, H, T, Dh = x.shape
    half = Dh // 2
    freqs = base ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = jnp.arange(T, dtype=jnp.float32)[:, None] * freqs[None, :]  # (T, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def rope_at(x: jax.Array, pos: jax.Array, base: float = 10000.0) -> jax.Array:
    """Rotary embedding of single-position vectors x (B, H, Dh) at integer
    position `pos` (traced i32 scalar) — `rope` evaluated at index pos, so
    cached keys rotated at insertion time stay consistent with queries."""
    Dh = x.shape[-1]
    half = Dh // 2
    freqs = base ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = pos.astype(jnp.float32) * freqs                  # (half,)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _attn_E(cfg: ModelConfig, bank: str) -> int:
    """Expert count of an attention projection bank under MoA/SwitchHead."""
    if cfg.attn_moe == "moa" and bank in ("q", "o"):
        return cfg.attn_moe_experts
    if cfg.attn_moe == "switchhead" and bank in ("v", "o"):
        return cfg.attn_moe_experts
    return 1


def init_attn_block(cfg: ModelConfig, key) -> Dict:
    D = cfg.d_model
    k = iter(jax.random.split(key, 8))
    init = fan_in_normal()
    p = {
        "w_q": init(next(k), bank_shape(_attn_E(cfg, "q"), D, D)),
        "w_k": init(next(k), bank_shape(_attn_E(cfg, "k"), D, D)),
        "w_v": init(next(k), bank_shape(_attn_E(cfg, "v"), D, D)),
        "w_o": init(next(k), bank_shape(_attn_E(cfg, "o"), D, D)),
    }
    if cfg.attn_moe != "none":
        p["router"] = init(next(k), (D, cfg.attn_moe_experts))
    return p


def attn_block(cfg: ModelConfig, p: Dict, x: jax.Array, *, window: Optional[int],
               key=None) -> Tuple[jax.Array, list]:
    """Causal attention; `window` = sliding window size (None = full causal).

    Returns (out, router stats list)."""
    B, T, D = x.shape
    H, Dh = cfg.n_heads, cfg.head_dim
    flat = x.reshape(B * T, D)
    stats: list = []

    r: Optional[Routing] = None
    if cfg.attn_moe != "none":
        r = route_tokens(flat, p["router"], top_k=1)
        stats.append(r)

    def proj(bank: str, inp):
        w = p[f"w_{bank}"]
        if w.ndim == 3 and w.shape[0] > 1:
            y = bank_apply(inp, w, r, cfg.moe_impl)
            if bank == "o":  # gate weight applied once, at the output bank
                y = y * jnp.sum(r.gates, axis=-1, keepdims=True)
            return y
        return bank_apply(inp, w, None)

    q = proj("q", flat).reshape(B, T, H, Dh).transpose(0, 2, 1, 3)
    kk = proj("k", flat).reshape(B, T, H, Dh).transpose(0, 2, 1, 3)
    v = proj("v", flat).reshape(B, T, H, Dh).transpose(0, 2, 1, 3)
    q, kk = rope(q), rope(kk)

    scores = jnp.einsum("bhtd,bhsd->bhts", q, kk) / jnp.sqrt(Dh)
    i = jnp.arange(T)[:, None]
    j = jnp.arange(T)[None, :]
    mask = i >= j
    if window is not None:
        mask = mask & (i - j < window)
    scores = jnp.where(mask, scores, -1e9)
    attn = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhts,bhsd->bhtd", attn, v)
    ctx = ctx.transpose(0, 2, 1, 3).reshape(B * T, D)
    out = proj("o", ctx)
    return out.reshape(B, T, D), stats


def attn_block_prefill(cfg: ModelConfig, p: Dict, x: jax.Array):
    """Parallel-in-T SWA forward from position 0 that also builds the rolling
    KV caches `attn_block_step` continues from.

    The caches hold the last `cfg.window` post-RoPE key rows and value rows in
    the (B, W, D) row layout of the step path, oldest slot first. Prompts
    shorter than the window leave zero rows at the front; the step's position
    validity mask makes them unreadable, so their contents never matter.

    Args:
      x: (B, T, D) token representations, positions 0..T-1.
    Returns:
      (out (B, T, D), k_cache (B, W, D), v_cache (B, W, D)).
    """
    B, T, D = x.shape
    H, Dh = cfg.n_heads, cfg.head_dim
    W = cfg.window
    flat = x.reshape(B * T, D)

    r: Optional[Routing] = None
    if cfg.attn_moe != "none":
        r = route_tokens(flat, p["router"], top_k=1)

    def proj(bank: str, inp):
        w = p[f"w_{bank}"]
        if w.ndim == 3 and w.shape[0] > 1:
            y = bank_apply(inp, w, r)
            if bank == "o":
                y = y * jnp.sum(r.gates, axis=-1, keepdims=True)
            return y
        return bank_apply(inp, w, None)

    q = proj("q", flat).reshape(B, T, H, Dh).transpose(0, 2, 1, 3)
    kk = proj("k", flat).reshape(B, T, H, Dh).transpose(0, 2, 1, 3)
    v_rows = proj("v", flat).reshape(B, T, D)              # step cache layout
    v = v_rows.reshape(B, T, H, Dh).transpose(0, 2, 1, 3)
    q, kk = rope(q), rope(kk)                              # absolute pos 0..T-1

    scores = jnp.einsum("bhtd,bhsd->bhts", q, kk) / jnp.sqrt(Dh)
    i = jnp.arange(T)[:, None]
    j = jnp.arange(T)[None, :]
    mask = (i >= j) & (i - j < W)
    scores = jnp.where(mask, scores, -1e9)
    attn = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhts,bhsd->bhtd", attn, v)
    out = proj("o", ctx.transpose(0, 2, 1, 3).reshape(B * T, D))

    k_rows = kk.transpose(0, 2, 1, 3).reshape(B, T, D)     # post-RoPE keys
    k_cache = jnp.pad(k_rows, ((0, 0), (W, 0), (0, 0)))[:, T:, :]
    v_cache = jnp.pad(v_rows, ((0, 0), (W, 0), (0, 0)))[:, T:, :]
    return out.reshape(B, T, D), k_cache, v_cache


def attn_block_step(cfg: ModelConfig, p: Dict, x: jax.Array,
                    k_cache: jax.Array, v_cache: jax.Array, pos: jax.Array):
    """One-token forward of `attn_block` on rolling KV caches.

    Args:
      x: (B, D) token representations.
      k_cache/v_cache: (B, W, D) rolling caches, oldest slot first. Keys are
        stored post-RoPE (rotated at their absolute positions, so relative
        attention falls out of the dot product). W = cfg.window: the cache
        capacity IS the sliding window, which requires cfg.window > 0.
      pos: traced i32 scalar, the absolute position of the incoming token.
    Returns:
      (out (B, D), new_k_cache, new_v_cache).
    """
    B, D = x.shape
    H, Dh = cfg.n_heads, cfg.head_dim
    W = k_cache.shape[1]

    r: Optional[Routing] = None
    if cfg.attn_moe != "none":
        r = route_tokens(x, p["router"], top_k=1)

    def proj(bank: str, inp):
        w = p[f"w_{bank}"]
        if w.ndim == 3 and w.shape[0] > 1:
            y = bank_apply(inp, w, r)
            if bank == "o":
                y = y * jnp.sum(r.gates, axis=-1, keepdims=True)
            return y
        return bank_apply(inp, w, None)

    q = rope_at(proj("q", x).reshape(B, H, Dh), pos)
    k = rope_at(proj("k", x).reshape(B, H, Dh), pos)
    v = proj("v", x)

    k_cache = jnp.concatenate([k_cache[:, 1:], k.reshape(B, 1, D)], axis=1)
    v_cache = jnp.concatenate([v_cache[:, 1:], v[:, None, :]], axis=1)
    kc = k_cache.reshape(B, W, H, Dh)
    vc = v_cache.reshape(B, W, H, Dh)

    scores = jnp.einsum("bhd,bwhd->bhw", q, kc) / jnp.sqrt(Dh)
    # Slot w holds absolute position pos-(W-1)+w; valid iff that position
    # exists (>= 0) — exactly the (i>=j) & (i-j<window) training mask.
    valid = jnp.arange(W) >= (W - 1 - pos)
    scores = jnp.where(valid[None, None, :], scores, -1e9)
    attn = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhw,bwhd->bhd", attn, vc).reshape(B, D)
    out = proj("o", ctx)
    return out, k_cache, v_cache


def attn_block_prefill_full(cfg: ModelConfig, p: Dict, x: jax.Array, cap: int):
    """Parallel-in-T full-causal forward from position 0 that also builds the
    capped position-indexed KV caches `attn_block_step_full` continues from.

    Unlike the rolling SWA caches, slot c of a full-attention cache holds
    absolute position c: rows 0..T-1 are the prompt's post-RoPE keys/values
    and rows T..cap-1 stay zero until decode writes them. The step's validity
    mask (`slot <= pos`) keeps the unwritten tail unreadable.

    Args:
      x: (B, T, D) token representations, positions 0..T-1. Requires T <= cap.
    Returns:
      (out (B, T, D), k_cache (B, cap, D), v_cache (B, cap, D)).
    """
    B, T, D = x.shape
    H, Dh = cfg.n_heads, cfg.head_dim
    if T > cap:
        raise ValueError(f"prompt length {T} exceeds kv_cap {cap}")
    flat = x.reshape(B * T, D)

    r: Optional[Routing] = None
    if cfg.attn_moe != "none":
        r = route_tokens(flat, p["router"], top_k=1)

    def proj(bank: str, inp):
        w = p[f"w_{bank}"]
        if w.ndim == 3 and w.shape[0] > 1:
            y = bank_apply(inp, w, r)
            if bank == "o":
                y = y * jnp.sum(r.gates, axis=-1, keepdims=True)
            return y
        return bank_apply(inp, w, None)

    q = proj("q", flat).reshape(B, T, H, Dh).transpose(0, 2, 1, 3)
    kk = proj("k", flat).reshape(B, T, H, Dh).transpose(0, 2, 1, 3)
    v_rows = proj("v", flat).reshape(B, T, D)              # step cache layout
    v = v_rows.reshape(B, T, H, Dh).transpose(0, 2, 1, 3)
    q, kk = rope(q), rope(kk)                              # absolute pos 0..T-1

    scores = jnp.einsum("bhtd,bhsd->bhts", q, kk) / jnp.sqrt(Dh)
    i = jnp.arange(T)[:, None]
    j = jnp.arange(T)[None, :]
    scores = jnp.where(i >= j, scores, -1e9)
    attn = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhts,bhsd->bhtd", attn, v)
    out = proj("o", ctx.transpose(0, 2, 1, 3).reshape(B * T, D))

    k_rows = kk.transpose(0, 2, 1, 3).reshape(B, T, D)     # post-RoPE keys
    k_cache = jnp.pad(k_rows, ((0, 0), (0, cap - T), (0, 0)))
    v_cache = jnp.pad(v_rows, ((0, 0), (0, cap - T), (0, 0)))
    return out.reshape(B, T, D), k_cache, v_cache


def attn_block_step_full(cfg: ModelConfig, p: Dict, x: jax.Array,
                         k_cache: jax.Array, v_cache: jax.Array, pos: jax.Array):
    """One-token forward of full causal attention on capped KV caches.

    Args:
      x: (B, D) token representations.
      k_cache/v_cache: (B, cap, D) position-indexed caches; slot c holds the
        post-RoPE key/value row of absolute position c (zeros where unwritten).
        The incoming token is scatter-written at slot `pos`, so the caller
        must guarantee pos < cap — XLA clamps out-of-range dynamic-update
        indices, which would silently overwrite slot cap-1 (the rust
        coordinator enforces the cap host-side before each step).
      pos: traced i32 scalar, the absolute position of the incoming token.
    Returns:
      (out (B, D), new_k_cache, new_v_cache).
    """
    B, D = x.shape
    H, Dh = cfg.n_heads, cfg.head_dim
    cap = k_cache.shape[1]

    r: Optional[Routing] = None
    if cfg.attn_moe != "none":
        r = route_tokens(x, p["router"], top_k=1)

    def proj(bank: str, inp):
        w = p[f"w_{bank}"]
        if w.ndim == 3 and w.shape[0] > 1:
            y = bank_apply(inp, w, r)
            if bank == "o":
                y = y * jnp.sum(r.gates, axis=-1, keepdims=True)
            return y
        return bank_apply(inp, w, None)

    q = rope_at(proj("q", x).reshape(B, H, Dh), pos)
    k = rope_at(proj("k", x).reshape(B, H, Dh), pos)
    v = proj("v", x)

    k_cache = jax.lax.dynamic_update_slice_in_dim(
        k_cache, k.reshape(B, 1, D), pos, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        v_cache, v[:, None, :], pos, axis=1)
    kc = k_cache.reshape(B, cap, H, Dh)
    vc = v_cache.reshape(B, cap, H, Dh)

    scores = jnp.einsum("bhd,bchd->bhc", q, kc) / jnp.sqrt(Dh)
    # Slot c holds absolute position c; valid iff already written (c <= pos)
    # — exactly the causal i >= j training mask.
    valid = jnp.arange(cap) <= pos
    scores = jnp.where(valid[None, None, :], scores, -1e9)
    attn = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhc,bchd->bhd", attn, vc).reshape(B, D)
    out = proj("o", ctx)
    return out, k_cache, v_cache
