"""Expert-bank linear projection: the unit RoM expertizes.

A bank is either dense (E == 1: a single weight matrix) or a stack of E expert
matrices dispatched by a `Routing`. Two implementations with identical
semantics:

  * "onehot":  dense one-hot einsum (E× compute; XLA-fusion friendly; also the
               oracle the grouped path is tested against).
  * "grouped": the Pallas megablocks grouped GEMM (token-linear compute).

The gate weights R_i are deliberately NOT applied here — Eq. 10-11 use the
bare top-K indicator for the Conv/Gate banks and Eq. 12 applies R once after
the Out bank; callers own that (see layers/router.combine_topk).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from compile.kernels.grouped_gemm import grouped_gemm
from compile.layers.router import Routing


def bank_apply(x: jax.Array, w: jax.Array, routing: Optional[Routing],
               impl: str = "onehot", block_size: int = 16) -> jax.Array:
    """Apply a projection bank to flat tokens.

    Args:
      x: (T, Din) tokens.
      w: (Din, Dout) dense weight, or (E, Din, Dout) expert bank.
      routing: required iff w is a bank with E > 1.
      impl: "onehot" | "grouped".
    Returns:
      (T, Dout) — for top-K > 1 the unweighted sum over selected experts
      (indicator semantics of Eq. 10-11).
    """
    if w.ndim == 2:
        return x @ w
    E = w.shape[0]
    if E == 1:
        return x @ w[0]
    assert routing is not None, "expert bank requires a routing decision"
    T, K = routing.route.shape
    acc = None
    for k in range(K):
        route_k = routing.route[:, k]
        if impl == "grouped":
            y = grouped_gemm(x, w, route_k, block_size, True)
        else:
            onehot = jax.nn.one_hot(route_k, E, dtype=x.dtype)
            y = jnp.einsum("te,td,edf->tf", onehot, x, w)
        acc = y if acc is None else acc + y
    return acc


def bank_shape(E: int, din: int, dout: int):
    """Shape of a bank parameter: dense (din,dout) when E==1 else (E,din,dout)."""
    return (din, dout) if E == 1 else (E, din, dout)
