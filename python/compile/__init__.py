"""Build-time compile path: JAX/Pallas model authoring + AOT lowering to HLO
text artifacts consumed by the rust coordinator. Never imported at runtime."""
