"""Model / training configuration shared between the python compile path and
the rust coordinator.

The same JSON document drives both sides:
  * python (`compile.aot`) builds the jax model, lowers it to HLO text and
    emits a manifest describing the flat parameter layout;
  * rust (`config::ModelConfig`) re-parses the JSON to size buffers, count
    FLOPS and drive experiments.

Keep this file dependency-free (no jax imports) so tests can import it
cheaply.
"""

from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

ARCHS = ("mamba", "mamba2", "gdn", "samba", "llama")
ROUTINGS = ("none", "shared", "independent")
MOE_IMPLS = ("onehot", "grouped")
SCAN_IMPLS = ("assoc", "loop", "pallas")
# Projection banks that may be expertized in a Mamba block (paper Fig 2 / Tab 1).
ROM_TARGETS = ("conv", "gate", "out", "dt", "x")


@dataclass
class MoEConfig:
    """Sparse-expert settings for one family of banks (RoM or FFN-MoE)."""

    num_experts: int = 1          # 1 == dense (no experts)
    top_k: int = 1
    jitter: float = 0.0           # multiplicative routing jitter (train only)
    balance_loss: float = 0.0     # aux load-balance loss coefficient (0 = off)
    straight_through: bool = True  # ST estimator through the discrete top-k

    @property
    def enabled(self) -> bool:
        return self.num_experts > 1


@dataclass
class ModelConfig:
    """One model variant of the zoo. Field names mirror rust config/model.rs."""

    name: str = "rom-tiny"
    arch: str = "samba"            # one of ARCHS
    vocab_size: int = 512
    d_model: int = 128
    n_layers: int = 4              # number of *blocks* (see block layout below)
    expand: int = 2                # Mamba inner expansion e (d_inner = e*d_model)
    d_state: int = 16
    dt_rank: int = 0               # 0 -> d_model//16 (paper: d_r = d_m/16)
    conv_kernel: int = 4
    n_heads: int = 4               # attention / mamba2 heads
    window: int = 64               # sliding-window size for SWA blocks
    mlp_mult: int = 2              # SwiGLU hidden multiple
    tie_embeddings: bool = True

    # --- sparse scaling ---------------------------------------------------
    # Which Mamba projection banks become experts; empty = dense Mamba.
    rom_targets: List[str] = field(default_factory=list)
    # "shared": one router per block reused by every bank (RoM, Eq. 9-13).
    # "independent": one router per bank (MoE-Mamba baseline, Fig 2 / Tab 4).
    routing: str = "shared"
    rom: MoEConfig = field(default_factory=MoEConfig)
    ffn_moe: MoEConfig = field(default_factory=MoEConfig)  # FFN experts (samba/llama)
    # Hybrid RoM+FFN-MoE (App. A.2 Eq. 14-15): MLP experts reuse the routing
    # decision of the preceding RoM layer instead of learning their own router.
    ffn_moe_share_router: bool = False
    attn_moe: str = "none"         # "none" | "moa" | "switchhead" (Table 1 baselines)
    attn_moe_experts: int = 8
    moe_impl: str = "onehot"       # "onehot" (oracle) | "grouped" (megablocks-style)
    scan_impl: str = "assoc"       # "assoc" | "loop" | "pallas"

    # --- training-time shapes baked into artifacts ------------------------
    batch_size: int = 8
    seq_len: int = 128
    micro_batch: int = 0           # 0 -> no grad-accum artifacts
    eval_lens: List[int] = field(default_factory=lambda: [128, 256, 512])
    # Batch rows baked into the prefill_L{L}/decode_step generation artifacts
    # (the rust `rom generate` path chunks prompts into groups of this size).
    decode_batch: int = 2

    def __post_init__(self) -> None:
        if self.arch not in ARCHS:
            raise ValueError(f"unknown arch {self.arch!r}; expected one of {ARCHS}")
        if self.routing not in ROUTINGS:
            raise ValueError(f"unknown routing {self.routing!r}")
        if self.moe_impl not in MOE_IMPLS:
            raise ValueError(f"unknown moe_impl {self.moe_impl!r}")
        if self.scan_impl not in SCAN_IMPLS:
            raise ValueError(f"unknown scan_impl {self.scan_impl!r}")
        for t in self.rom_targets:
            if t not in ROM_TARGETS:
                raise ValueError(f"unknown rom target {t!r}; expected {ROM_TARGETS}")
        if self.dt_rank == 0:
            self.dt_rank = max(1, self.d_model // 16)
        if self.rom_targets and not self.rom.enabled:
            raise ValueError("rom_targets set but rom.num_experts <= 1")
        if self.decode_batch < 1:
            raise ValueError("decode_batch must be >= 1")

    # --- derived sizes ----------------------------------------------------
    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def kv_cap(self) -> int:
        """Decode KV-cache capacity for full-attention blocks (window <= 0):
        2x the longest context any artifact is built for, so every prefill
        length fits and generation can run well past training length. A
        derived quantity (not a stored field), mirrored by rust
        `ModelCfg::kv_cap` and recorded in the manifest's decode section."""
        return 2 * max([self.seq_len, *self.eval_lens])

    def block_layout(self) -> List[str]:
        """Per-layer block kinds, mirroring the paper's Figure 5 layouts.

        mamba/mamba2/gdn: n_layers SSM blocks.
        samba: repeating [mamba, swa, mlp] groups (n_layers counts groups).
        llama: repeating [swa, mlp] groups.
        """
        if self.arch in ("mamba", "mamba2", "gdn"):
            return [self.arch] * self.n_layers
        if self.arch == "samba":
            out: List[str] = []
            for _ in range(self.n_layers):
                out += ["mamba", "swa", "mlp"]
            return out
        if self.arch == "llama":
            out = []
            for _ in range(self.n_layers):
                out += ["swa", "mlp"]
            return out
        raise AssertionError(self.arch)

    # --- (de)serialization --------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "ModelConfig":
        d = dict(d)
        for k in ("rom", "ffn_moe"):
            if k in d and isinstance(d[k], dict):
                d[k] = MoEConfig(**d[k])
        return cls(**d)

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2, sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "ModelConfig":
        return cls.from_dict(json.loads(s))


def load_config(path: str) -> ModelConfig:
    with open(path) as f:
        doc = json.load(f)
    # Allow a combined {"model": {...}, "train": {...}} document.
    if "model" in doc:
        doc = doc["model"]
    return ModelConfig.from_dict(doc)
