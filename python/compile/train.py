"""Training/eval step builders lowered to HLO by compile.aot.

Signatures (mirrored in rust `runtime::artifact`):
  init : (seed i32[])                                   -> params
  step : (params, m, v, step f32, lr f32, tok, tgt)     -> (params, m, v, loss, load)
  grad : (params, gacc, tok, tgt)                       -> (gacc', loss, load)
  apply: (params, m, v, gsum, step f32, lr f32, n f32)  -> (params, m, v)
  eval : (params, tok, tgt)                             -> (nll_sum, count)

(grad's trailing `load` output is new: the rust session samples router
telemetry from it on the grad-accum path, and still accepts legacy grad
artifacts that emit only (gacc', loss).)

AdamW is implemented inline (no optax in the artifact path): beta1=0.9,
beta2=0.95, eps=1e-8, weight-decay 0.1, gradient clip 1.0 — the paper's §5.1
settings. The LR schedule itself lives in the rust coordinator and arrives as
the `lr` scalar each step.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from compile.config import ModelConfig
from compile.model import forward, init_params

BETA1, BETA2, EPS = 0.9, 0.95, 1e-8
WEIGHT_DECAY = 0.1
CLIP = 1.0


def loss_fn(cfg: ModelConfig, params: Dict, tokens, targets, key=None):
    """Mean token cross-entropy + optional balance loss. Returns (loss, aux)."""
    logits, aux = forward(cfg, params, tokens, key)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    loss = jnp.mean(nll)
    total = loss
    if cfg.rom.balance_loss > 0 or cfg.ffn_moe.balance_loss > 0:
        coef = max(cfg.rom.balance_loss, cfg.ffn_moe.balance_loss)
        total = total + coef * aux.balance
    return total, (loss, aux)


def _clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-12))
    return jax.tree_util.tree_map(lambda g: g * scale, grads)


def adamw_update(params, m, v, grads, step, lr):
    """One AdamW update; step is 1-based (f32 scalar)."""
    b1c = 1.0 - BETA1 ** step
    b2c = 1.0 - BETA2 ** step

    def upd(p, m_, v_, g):
        m_n = BETA1 * m_ + (1.0 - BETA1) * g
        v_n = BETA2 * v_ + (1.0 - BETA2) * g * g
        mhat = m_n / b1c
        vhat = v_n / b2c
        p_n = p - lr * (mhat / (jnp.sqrt(vhat) + EPS) + WEIGHT_DECAY * p)
        return p_n, m_n, v_n

    flat_p, td = jax.tree_util.tree_flatten(params)
    flat_m = jax.tree_util.tree_leaves(m)
    flat_v = jax.tree_util.tree_leaves(v)
    flat_g = jax.tree_util.tree_leaves(grads)
    out = [upd(p, m_, v_, g) for p, m_, v_, g in zip(flat_p, flat_m, flat_v, flat_g)]
    new_p = jax.tree_util.tree_unflatten(td, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(td, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(td, [o[2] for o in out])
    return new_p, new_m, new_v


def make_init_fn(cfg: ModelConfig):
    def init(seed):
        key = jax.random.PRNGKey(seed)
        return init_params(cfg, key)

    return init


def make_step_fn(cfg: ModelConfig):
    """Fused fwd+bwd+AdamW step (the fast path)."""

    def step(params, m, v, stepnum, lr, tokens, targets):
        key = jax.random.PRNGKey(jnp.astype(stepnum, jnp.int32)) if (
            cfg.rom.jitter > 0 or cfg.ffn_moe.jitter > 0) else None
        (_, (loss, aux)), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, tokens, targets, key), has_aux=True)(params)
        grads = _clip_by_global_norm(grads, CLIP)
        params, m, v = adamw_update(params, m, v, grads, stepnum, lr)
        return params, m, v, loss, aux.load

    return step


def make_grad_fn(cfg: ModelConfig):
    """Microbatch gradient-accumulation step (the grad-accum path).

    Returns the router load alongside (gacc', loss) so the coordinator's
    expert monitor observes dispatch under --accum too (it samples the last
    microbatch of each optimizer step)."""

    def grad(params, gacc, tokens, targets):
        (_, (loss, aux)), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, tokens, targets, None), has_aux=True)(params)
        gacc = jax.tree_util.tree_map(jnp.add, gacc, grads)
        return gacc, loss, aux.load

    return grad


def make_apply_fn(cfg: ModelConfig):
    def apply(params, m, v, gsum, stepnum, lr, nmicro):
        grads = jax.tree_util.tree_map(lambda g: g / nmicro, gsum)
        grads = _clip_by_global_norm(grads, CLIP)
        return adamw_update(params, m, v, grads, stepnum, lr)

    return apply


def make_eval_fn(cfg: ModelConfig):
    def evaluate(params, tokens, targets):
        logits, _ = forward(cfg, params, tokens, None)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
        return jnp.sum(nll), jnp.asarray(nll.size, jnp.float32)

    return evaluate


def make_eval_last_fn(cfg: ModelConfig):
    """NLL of the FINAL position only — the LAMBADA-style probe primitive
    (rust `coordinator::downstream` ranks cloze options with this)."""

    def evaluate(params, tokens, targets):
        logits, _ = forward(cfg, params, tokens, None)
        logp = jax.nn.log_softmax(logits[:, -1, :], axis=-1)
        nll = -jnp.take_along_axis(logp, targets[:, -1][..., None], axis=-1)[..., 0]
        return jnp.sum(nll), jnp.asarray(nll.size, jnp.float32)

    return evaluate


def zeros_like_params(cfg: ModelConfig) -> Tuple:
    """Abstract-eval a zeroed param pytree (for grad-accum buffers)."""
    shapes = jax.eval_shape(make_init_fn(cfg), jnp.zeros((), jnp.int32))
    return jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype), shapes)
