"""L1 kernel correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes/dtypes within CPU-feasible bounds; fixed-seed
examples pin the exact allclose tolerances. These tests are the core
correctness signal for everything the rust coordinator later executes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import grouped_gemm, make_group_plan, selective_scan, short_conv
from compile.kernels.grouped_gemm import gather_tokens, scatter_tokens
from compile.kernels.ref import (
    grouped_gemm_ref,
    selective_scan_assoc,
    selective_scan_ref,
    short_conv_ref,
)

jax.config.update("jax_enable_x64", False)

HYP = dict(max_examples=12, deadline=None)


def _scan_inputs(key, B, T, Di, N, dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    u = jax.random.normal(ks[0], (B, T, Di), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, Di), dtype))
    A = -jnp.exp(jax.random.normal(ks[2], (Di, N), dtype))
    Bm = jax.random.normal(ks[3], (B, T, N), dtype)
    Cm = jax.random.normal(ks[4], (B, T, N), dtype)
    D = jax.random.normal(ks[5], (Di,), dtype)
    return u, dt, A, Bm, Cm, D


class TestSelectiveScan:
    def test_fixed(self):
        args = _scan_inputs(jax.random.PRNGKey(0), 2, 64, 16, 8)
        y_ref = selective_scan_ref(*args)
        y_pal = selective_scan(*args, chunk=16)
        np.testing.assert_allclose(y_ref, y_pal, rtol=2e-5, atol=2e-5)

    def test_assoc_matches_loop(self):
        args = _scan_inputs(jax.random.PRNGKey(1), 3, 48, 12, 4)
        np.testing.assert_allclose(
            selective_scan_ref(*args),
            selective_scan_assoc(*args, chunk=16),
            rtol=2e-5,
            atol=2e-5,
        )

    @settings(**HYP)
    @given(
        seed=st.integers(0, 2**31 - 1),
        B=st.integers(1, 3),
        T=st.sampled_from([8, 16, 32, 64]),
        Di=st.sampled_from([4, 8, 24]),
        N=st.sampled_from([2, 4, 16]),
        chunk=st.sampled_from([4, 8, 16]),
    )
    def test_sweep(self, seed, B, T, Di, N, chunk):
        args = _scan_inputs(jax.random.PRNGKey(seed), B, T, Di, N)
        y_ref = selective_scan_ref(*args)
        y_pal = selective_scan(*args, chunk=chunk)
        np.testing.assert_allclose(y_ref, y_pal, rtol=5e-5, atol=5e-5)

    def test_chunk_not_dividing_falls_back(self):
        args = _scan_inputs(jax.random.PRNGKey(2), 1, 30, 4, 2)
        y_ref = selective_scan_ref(*args)
        y_pal = selective_scan(*args, chunk=16)  # 16 does not divide 30
        np.testing.assert_allclose(y_ref, y_pal, rtol=5e-5, atol=5e-5)

    def test_decay_state(self):
        # With dt*A very negative, the state forgets: y ~= local response + D*u.
        u, dt, A, Bm, Cm, D = _scan_inputs(jax.random.PRNGKey(3), 1, 16, 4, 2)
        y = selective_scan(u, dt, A * 100.0, Bm, Cm, D, chunk=8)
        assert np.all(np.isfinite(np.asarray(y)))

    def test_grad_matches_ref(self):
        args = _scan_inputs(jax.random.PRNGKey(4), 1, 16, 4, 2)

        def f_ref(u):
            return jnp.sum(jnp.tanh(selective_scan_ref(u, *args[1:])))

        def f_pal(u):
            return jnp.sum(jnp.tanh(selective_scan(u, *args[1:], chunk=8)))

        g_ref = jax.grad(f_ref)(args[0])
        g_pal = jax.grad(f_pal)(args[0])
        np.testing.assert_allclose(g_ref, g_pal, rtol=1e-4, atol=1e-4)


class TestGroupedGemm:
    def _inputs(self, seed, T, D, F, E):
        ks = jax.random.split(jax.random.PRNGKey(seed), 3)
        x = jax.random.normal(ks[0], (T, D))
        w = jax.random.normal(ks[1], (E, D, F))
        route = jax.random.randint(ks[2], (T,), 0, E)
        return x, w, route

    def test_fixed(self):
        x, w, route = self._inputs(0, 64, 16, 24, 8)
        np.testing.assert_allclose(
            grouped_gemm_ref(x, w, route),
            grouped_gemm(x, w, route, 16, True),
            rtol=1e-5,
            atol=1e-5,
        )

    @settings(**HYP)
    @given(
        seed=st.integers(0, 2**31 - 1),
        T=st.integers(1, 70),
        D=st.sampled_from([3, 8, 17]),
        F=st.sampled_from([2, 8, 19]),
        E=st.sampled_from([1, 2, 4, 8]),
        block=st.sampled_from([4, 8, 16]),
    )
    def test_sweep(self, seed, T, D, F, E, block):
        x, w, route = self._inputs(seed, T, D, F, E)
        np.testing.assert_allclose(
            grouped_gemm_ref(x, w, route),
            grouped_gemm(x, w, route, block, True),
            rtol=1e-4,
            atol=1e-4,
        )

    def test_all_one_expert(self):
        # Degenerate routing: everything to expert 2 == plain matmul.
        x, w, _ = self._inputs(7, 32, 8, 8, 4)
        route = jnp.full((32,), 2, dtype=jnp.int32)
        np.testing.assert_allclose(
            x @ w[2], grouped_gemm(x, w, route, 8, True), rtol=1e-5, atol=1e-5
        )

    def test_grads(self):
        x, w, route = self._inputs(9, 40, 6, 10, 4)

        def f(fn):
            def loss(x, w):
                return jnp.sum(jnp.sin(fn(x, w)))

            return jax.grad(loss, argnums=(0, 1))(x, w)

        gx_r, gw_r = f(lambda x, w: grouped_gemm_ref(x, w, route))
        gx_k, gw_k = f(lambda x, w: grouped_gemm(x, w, route, 8, True))
        np.testing.assert_allclose(gx_r, gx_k, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(gw_r, gw_k, rtol=1e-4, atol=1e-5)

    @settings(**HYP)
    @given(seed=st.integers(0, 2**31 - 1), T=st.integers(2, 40),
           E=st.sampled_from([2, 4, 8]))
    def test_grad_sweep(self, seed, T, E):
        x, w, route = self._inputs(seed, T, 5, 7, E)

        def loss_k(x, w):
            return jnp.sum(grouped_gemm(x, w, route, 8, True) ** 2)

        def loss_r(x, w):
            return jnp.sum(grouped_gemm_ref(x, w, route) ** 2)

        gk = jax.grad(loss_k, argnums=(0, 1))(x, w)
        gr = jax.grad(loss_r, argnums=(0, 1))(x, w)
        np.testing.assert_allclose(gr[0], gk[0], rtol=2e-4, atol=1e-4)
        np.testing.assert_allclose(gr[1], gk[1], rtol=2e-4, atol=1e-4)


class TestGroupPlan:
    @settings(**HYP)
    @given(seed=st.integers(0, 2**31 - 1), T=st.integers(1, 100),
           E=st.sampled_from([1, 2, 4, 8]), block=st.sampled_from([4, 8, 16]))
    def test_plan_invariants(self, seed, T, E, block):
        route = jax.random.randint(jax.random.PRNGKey(seed), (T,), 0, E)
        plan = make_group_plan(route, E, block)
        pos = np.asarray(plan.pos)
        be = np.asarray(plan.block_expert)
        # Destinations are unique and in range.
        assert len(set(pos.tolist())) == T
        assert pos.min() >= 0 and pos.max() < plan.padded_len
        # Every token lands in a block labelled with its own expert.
        r = np.asarray(route)
        assert np.all(be[pos // block] == r)
        # Scatter/gather round-trips.
        x = np.random.RandomState(seed % 2**31).randn(T, 3).astype(np.float32)
        xp = scatter_tokens(jnp.asarray(x), plan)
        np.testing.assert_allclose(gather_tokens(xp, plan), x)


class TestShortConv:
    def test_fixed(self):
        ks = jax.random.split(jax.random.PRNGKey(0), 2)
        x = jax.random.normal(ks[0], (2, 32, 8))
        w = jax.random.normal(ks[1], (4, 8)) * 0.5
        np.testing.assert_allclose(
            short_conv_ref(x, w), short_conv(x, w), rtol=1e-5, atol=1e-6
        )

    @settings(**HYP)
    @given(
        seed=st.integers(0, 2**31 - 1),
        B=st.integers(1, 3),
        T=st.integers(4, 48),
        Di=st.sampled_from([1, 4, 9]),
        k=st.sampled_from([2, 3, 4]),
    )
    def test_sweep(self, seed, B, T, Di, k):
        ks = jax.random.split(jax.random.PRNGKey(seed), 2)
        x = jax.random.normal(ks[0], (B, T, Di))
        w = jax.random.normal(ks[1], (k, Di)) * 0.5
        np.testing.assert_allclose(
            short_conv_ref(x, w), short_conv(x, w), rtol=1e-5, atol=1e-5
        )

    def test_causality(self):
        # Output at position t must not depend on inputs after t.
        ks = jax.random.split(jax.random.PRNGKey(5), 2)
        x = jax.random.normal(ks[0], (1, 16, 4))
        w = jax.random.normal(ks[1], (4, 4))
        y0 = np.asarray(short_conv(x, w))
        x2 = x.at[:, 10:].set(99.0)
        y2 = np.asarray(short_conv(x2, w))
        np.testing.assert_allclose(y0[:, :10], y2[:, :10], rtol=1e-6, atol=1e-6)

    def test_grad_matches_ref(self):
        ks = jax.random.split(jax.random.PRNGKey(6), 2)
        x = jax.random.normal(ks[0], (1, 12, 4))
        w = jax.random.normal(ks[1], (4, 4)) * 0.3
        g_r = jax.grad(lambda w: jnp.sum(short_conv_ref(x, w) ** 2))(w)
        g_k = jax.grad(lambda w: jnp.sum(short_conv(x, w) ** 2))(w)
        np.testing.assert_allclose(g_r, g_k, rtol=1e-4, atol=1e-5)
