"""AOT pipeline: HLO text is parseable-shaped, manifest consistent with the
abstract param tree, presets emit configs, analysis numbers are coherent."""

import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import analysis, train
from compile.aot import lower_variant, param_manifest, to_hlo_text
from compile.config import ModelConfig, MoEConfig
from compile.presets import all_presets, emit_configs


def tiny_cfg():
    return ModelConfig(
        name="aot-test", arch="mamba", n_layers=2, d_model=32, vocab_size=64,
        batch_size=2, seq_len=16, eval_lens=[16],
        rom_targets=["conv", "gate", "out"], routing="shared",
        rom=MoEConfig(num_experts=4))


def test_hlo_text_has_entry(tmp_path):
    cfg = tiny_cfg()
    lowered = jax.jit(train.make_init_fn(cfg)).lower(
        jax.ShapeDtypeStruct((), jnp.int32))
    text = to_hlo_text(lowered)
    assert "ENTRY" in text and "HloModule" in text
    # XLA 0.5.1 parser compatibility: no opcodes newer than the image's xla.
    for bad in ("erf(", "topk(", " tan("):
        assert bad not in text, f"incompatible opcode {bad!r} in HLO"


def test_param_manifest_matches_tree():
    cfg = tiny_cfg()
    leaves = param_manifest(cfg)
    params = jax.jit(train.make_init_fn(cfg))(jnp.asarray(0, jnp.int32))
    flat = jax.tree_util.tree_leaves(params)
    assert len(leaves) == len(flat)
    for spec, leaf in zip(leaves, flat):
        assert tuple(spec["shape"]) == leaf.shape
        assert spec["dtype"] == str(leaf.dtype)
    # Names are unique and stable.
    names = [s["name"] for s in leaves]
    assert len(set(names)) == len(names)


def test_lower_variant_writes_all_artifacts(tmp_path):
    cfg = tiny_cfg()
    man = lower_variant(cfg, str(tmp_path))
    expected = {"init.hlo.txt", "step.hlo.txt", "grad.hlo.txt", "apply.hlo.txt",
                "eval_L16.hlo.txt", "eval_last_L16.hlo.txt",
                "decode_step.hlo.txt", "prefill_L16.hlo.txt", "manifest.json"}
    assert expected.issubset(set(os.listdir(tmp_path)))
    with open(tmp_path / "manifest.json") as f:
        doc = json.load(f)
    assert doc["num_param_leaves"] == len(doc["params"])
    assert doc["analysis"]["total_params"] > doc["analysis"]["active_params"]
    assert man["name"] == "aot-test"


def test_decode_manifest_section(tmp_path):
    from compile import decode

    cfg = tiny_cfg()
    man = lower_variant(cfg, str(tmp_path))
    dec = man["decode"]
    assert dec is not None and man["decode_unsupported"] is None
    assert dec["batch"] == cfg.decode_batch
    assert dec["prefill_lens"] == cfg.eval_lens
    assert dec["kv_cap"] is None  # pure-SSM layout: no full-attn cache lane
    assert dec["state"] == decode.state_spec(cfg)
    assert dec["state"][0] == {"name": "pos", "shape": [], "dtype": "int32"}
    # Decode HLO obeys the same XLA 0.5.1 parser constraints as training.
    for stem in ("decode_step", "prefill_L16"):
        with open(tmp_path / f"{stem}.hlo.txt") as f:
            text = f.read()
        assert "ENTRY" in text and "HloModule" in text
        for bad in ("erf(", "topk(", " tan("):
            assert bad not in text, f"incompatible opcode {bad!r} in {stem}"


def test_full_attention_variant_emits_decode_with_kv_cap(tmp_path):
    cfg = ModelConfig(name="aot-llama", arch="llama", n_layers=1, d_model=32,
                      vocab_size=64, window=0, batch_size=2, seq_len=16,
                      eval_lens=[16])
    man = lower_variant(cfg, str(tmp_path))
    assert man["decode_unsupported"] is None
    dec = man["decode"]
    assert dec["kv_cap"] == cfg.kv_cap == 32
    caches = [s for s in dec["state"] if s["name"].endswith("cache")]
    assert caches and all(s["shape"][1] == dec["kv_cap"] for s in caches)
    assert {"decode_step.hlo.txt", "prefill_L16.hlo.txt"} <= set(
        os.listdir(tmp_path))


def test_emit_configs_roundtrip(tmp_path):
    paths = emit_configs(str(tmp_path))
    assert len(paths) == len(all_presets())
    for p in paths[:5]:
        with open(p) as f:
            doc = json.load(f)
        cfg = ModelConfig.from_dict(doc)
        assert cfg.name == os.path.splitext(os.path.basename(p))[0]


def test_analysis_consistency_across_presets():
    for name, cfg in list(all_presets().items())[:8]:
        total, active = analysis.param_counts(cfg)
        assert active <= total, name
        if cfg.rom.enabled or cfg.ffn_moe.enabled:
            assert active < total, f"{name} should be sparse"
        else:
            assert active == total, f"{name} should be dense"
        assert analysis.flops_per_token(cfg, 128) > 0


def test_ladder_is_monotone():
    """Fig 3's x-axis: active params must increase along the scale ladder."""
    from compile.presets import LADDER, get_preset
    prev = 0
    for scale in LADDER:
        _, active = analysis.param_counts(get_preset(f"mamba-{scale}"))
        assert active > prev, scale
        prev = active


def test_rom_total_ratio_matches_paper_shape():
    """Paper Tab 7: RoM 115M active / 710M total ~ 6x. Our tiny analogue
    should scale totals by >4x with 8 experts on conv/gate/out."""
    from compile.presets import get_preset
    t_d, a_d = analysis.param_counts(get_preset("mamba-tiny"))
    t_r, a_r = analysis.param_counts(get_preset("rom-tiny"))
    assert a_r < 1.15 * a_d  # same active (+ router)
    assert t_r > 4 * t_d, f"total ratio {t_r / t_d}"
