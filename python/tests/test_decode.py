"""Stateful decoding: stepwise prefill/decode must reproduce the full-window
forward logits position by position, across every block kind and routing
mode. This is the python-side half of the prefill+decode parity contract
(the rust integration test checks the same thing through the AOT artifacts
against the eval programs)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import decode
from compile.config import ModelConfig, MoEConfig
from compile.model import forward, init_params


def _cfg(**kw) -> ModelConfig:
    base = dict(
        name="decode-test", arch="mamba", n_layers=2, d_model=32,
        vocab_size=64, batch_size=2, seq_len=16, eval_lens=[8, 16],
        window=8, decode_batch=2)
    base.update(kw)
    return ModelConfig(**base)


CFGS = {
    "mamba-dense": _cfg(),
    "mamba-rom": _cfg(rom_targets=["conv", "gate", "out"], routing="shared",
                      rom=MoEConfig(num_experts=4)),
    "mamba-rom-all": _cfg(rom_targets=["conv", "gate", "out", "dt", "x"],
                          routing="shared", rom=MoEConfig(num_experts=4)),
    "mamba-independent": _cfg(rom_targets=["conv", "out"],
                              routing="independent",
                              rom=MoEConfig(num_experts=4, top_k=2)),
    "mamba2-rom": _cfg(arch="mamba2", rom=MoEConfig(num_experts=4)),
    "gdn-rom": _cfg(arch="gdn", rom=MoEConfig(num_experts=4)),
    "samba": _cfg(arch="samba", n_layers=1),
    "samba-rom-hybrid": _cfg(arch="samba", n_layers=1,
                             rom_targets=["conv", "gate", "out"],
                             routing="shared", rom=MoEConfig(num_experts=4),
                             ffn_moe=MoEConfig(num_experts=4),
                             ffn_moe_share_router=True),
    "samba-moa": _cfg(arch="samba", n_layers=1, attn_moe="moa",
                      attn_moe_experts=4),
    # Full attention (window=0) through the capped kv_cap caches: the llama
    # proxy and the attn+SSM hybrid the paper's §hybrid results headline.
    "llama-full": _cfg(arch="llama", window=0),
    "hybrid-full": _cfg(arch="samba", n_layers=1, window=0),
    "hybrid-full-rom": _cfg(arch="samba", n_layers=1, window=0,
                            rom_targets=["conv", "gate", "out"],
                            routing="shared", rom=MoEConfig(num_experts=4),
                            ffn_moe=MoEConfig(num_experts=4),
                            ffn_moe_share_router=True),
}


def _tokens(cfg, T, seed=0):
    rng = np.random.RandomState(seed)
    return jnp.asarray(rng.randint(0, cfg.vocab_size, size=(cfg.decode_batch, T)),
                       jnp.int32)


def _stepwise_logits(cfg, params, tokens):
    """Feed tokens one at a time through forward_step; stack the logits."""
    state = decode.init_state(cfg, batch=tokens.shape[0])
    outs = []
    for t in range(tokens.shape[1]):
        logits, state = decode.forward_step(cfg, params, tokens[:, t], state)
        outs.append(logits)
    return jnp.stack(outs, axis=1), state                  # (B, T, V)


@pytest.mark.parametrize("name", sorted(CFGS))
def test_stepwise_matches_full_forward(name):
    cfg = CFGS[name]
    T = 12
    params = init_params(cfg, jax.random.PRNGKey(0))
    tokens = _tokens(cfg, T)
    full, _ = forward(cfg, params, tokens, None)
    stepped, state = _stepwise_logits(cfg, params, tokens)
    # Sequential-vs-chunked scan reassociation gives tiny fp drift only.
    np.testing.assert_allclose(np.asarray(stepped), np.asarray(full),
                               rtol=2e-4, atol=2e-4)
    assert int(state[0]) == T


def test_sliding_window_parity_beyond_window():
    """Positions past the SWA window exercise cache eviction: parity must
    hold once tokens start falling out of the rolling KV cache."""
    cfg = _cfg(arch="samba", n_layers=1, window=4)
    T = 10  # > 2 * window
    params = init_params(cfg, jax.random.PRNGKey(1))
    tokens = _tokens(cfg, T, seed=3)
    full, _ = forward(cfg, params, tokens, None)
    stepped, _ = _stepwise_logits(cfg, params, tokens)
    np.testing.assert_allclose(np.asarray(stepped), np.asarray(full),
                               rtol=2e-4, atol=2e-4)


def test_stepwise_prefill_equals_explicit_steps():
    """The sequential reference prefill (lax.scan over the step body) returns
    exactly the state and last logits of T explicit decode steps (same
    computation by construction; this pins the jit/scan plumbing that makes
    it a trustworthy oracle for the chunk-parallel prefill)."""
    cfg = CFGS["mamba-rom"]
    T = 8
    params = init_params(cfg, jax.random.PRNGKey(2))
    tokens = _tokens(cfg, T, seed=5)
    logits, state = jax.jit(decode.make_stepwise_prefill_fn(cfg))(params, tokens)
    stepped, sstate = _stepwise_logits(cfg, params, tokens)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(stepped[:, -1]),
                               rtol=1e-5, atol=1e-5)
    assert len(state) == len(sstate)
    for a, b in zip(state, sstate):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("name", sorted(CFGS))
def test_parallel_prefill_matches_stepwise(name):
    """The chunk-parallel prefill (what `aot` lowers as prefill_L{L}) must
    reproduce the sequential step-scan prefill — final packed state AND last
    logits — at every artifact length, for every layout and routing mode.
    Tolerance 2e-4 covers scan-reassociation fp drift only; a routing flip or
    state-layout bug blows straight past it."""
    cfg = CFGS[name]
    params = init_params(cfg, jax.random.PRNGKey(4))
    parallel = jax.jit(decode.make_prefill_fn(cfg))
    stepwise = jax.jit(decode.make_stepwise_prefill_fn(cfg))
    spec = decode.state_spec(cfg)
    for L in cfg.eval_lens:
        tokens = _tokens(cfg, L, seed=L)
        lg_p, st_p = parallel(params, tokens)
        lg_s, st_s = stepwise(params, tokens)
        np.testing.assert_allclose(np.asarray(lg_p), np.asarray(lg_s),
                                   rtol=2e-4, atol=2e-4,
                                   err_msg=f"{name} L={L} logits")
        assert len(st_p) == len(st_s) == len(spec)
        for a, b, s in zip(st_p, st_s, spec):
            assert a.shape == b.shape and a.dtype == b.dtype, (name, s["name"])
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=2e-4,
                                       err_msg=f"{name} L={L} {s['name']}")


def test_parallel_prefill_short_prompt_state_padding():
    """Prompts shorter than the conv kernel and the SWA window exercise the
    zero left-padding of the extracted conv windows and KV caches; decode must
    continue seamlessly from that padded state."""
    cfg = CFGS["samba-rom-hybrid"]
    T, P = 12, 2                       # P < conv_kernel-1 and P < window
    params = init_params(cfg, jax.random.PRNGKey(5))
    tokens = _tokens(cfg, T, seed=11)
    full, _ = forward(cfg, params, tokens, None)
    logits, state = jax.jit(decode.make_prefill_fn(cfg))(params, tokens[:, :P])
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full[:, P - 1]),
                               rtol=2e-4, atol=2e-4)
    step = jax.jit(decode.make_decode_step_fn(cfg))
    for t in range(P, T):
        logits, state = step(params, tokens[:, t], state)
        np.testing.assert_allclose(np.asarray(logits), np.asarray(full[:, t]),
                                   rtol=2e-4, atol=2e-4)


def test_prefill_then_decode_continues():
    """prefill(P tokens) + decode of the rest == full forward at those
    positions — the exact contract the rust generate path relies on."""
    cfg = CFGS["samba-rom-hybrid"]
    T, P = 12, 7
    params = init_params(cfg, jax.random.PRNGKey(3))
    tokens = _tokens(cfg, T, seed=7)
    full, _ = forward(cfg, params, tokens, None)
    logits, state = jax.jit(decode.make_prefill_fn(cfg))(params, tokens[:, :P])
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full[:, P - 1]),
                               rtol=2e-4, atol=2e-4)
    step = jax.jit(decode.make_decode_step_fn(cfg))
    for t in range(P, T):
        logits, state = step(params, tokens[:, t], state)
        np.testing.assert_allclose(np.asarray(logits), np.asarray(full[:, t]),
                                   rtol=2e-4, atol=2e-4)


def test_state_spec_matches_init_state():
    for name, cfg in CFGS.items():
        spec = decode.state_spec(cfg)
        state = decode.init_state(cfg)
        assert len(spec) == len(state), name
        assert spec[0] == {"name": "pos", "shape": [], "dtype": "int32"}
        names = [s["name"] for s in spec]
        assert len(set(names)) == len(names), name
        for s, arr in zip(spec, state):
            assert tuple(s["shape"]) == arr.shape, (name, s["name"])
            assert s["dtype"] == str(arr.dtype), (name, s["name"])


def test_full_attention_layouts_are_supported():
    """window <= 0 layouts decode through the capped kv_cap caches: no
    layout records decode_unsupported, and the cache leaves take capacity
    cfg.kv_cap instead of cfg.window."""
    for cfg in (_cfg(arch="llama", window=0),
                _cfg(arch="samba", n_layers=1, window=0),
                _cfg(window=0)):
        assert decode.unsupported_reason(cfg) is None, cfg.arch
    cfg = _cfg(arch="llama", window=0)
    assert cfg.kv_cap == 2 * max([cfg.seq_len, *cfg.eval_lens])
    caches = [s for s in decode.state_spec(cfg) if "cache" in s["name"]]
    assert caches, "llama layout must carry KV-cache leaves"
    for s in caches:
        assert s["shape"] == [cfg.decode_batch, cfg.kv_cap, cfg.d_model], s
    # Rolling SWA caches are untouched: capacity stays the window.
    swa = _cfg(arch="samba", n_layers=1, window=8)
    for s in decode.state_spec(swa):
        if "cache" in s["name"]:
            assert s["shape"][1] == swa.window, s


@pytest.mark.parametrize("name", ["llama-full", "hybrid-full"])
def test_full_attention_decode_to_cap_boundary(name):
    """Prefill + stepwise decode right up to the kv_cap boundary: the last
    emitted logits consume a state whose final cache write landed in slot
    kv_cap - 1 (prompt + new tokens == kv_cap), well past training seq_len.
    Parity against the full forward pins both the scatter-write indexing and
    the validity mask at the cap edge."""
    cfg = CFGS[name]
    T, P = cfg.kv_cap, cfg.eval_lens[0]           # 32 total, prefill 8
    assert T > cfg.seq_len, "cap boundary must lie beyond training length"
    params = init_params(cfg, jax.random.PRNGKey(6))
    tokens = _tokens(cfg, T, seed=13)
    full, _ = forward(cfg, params, tokens, None)
    logits, state = jax.jit(decode.make_prefill_fn(cfg))(params, tokens[:, :P])
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full[:, P - 1]),
                               rtol=2e-4, atol=2e-4)
    step = jax.jit(decode.make_decode_step_fn(cfg))
    for t in range(P, T):
        logits, state = step(params, tokens[:, t], state)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full[:, T - 1]),
                               rtol=5e-4, atol=5e-4)
    assert int(state[0]) == cfg.kv_cap
