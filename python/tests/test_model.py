"""L2 model invariants: shapes, routing semantics, dense==E1 equivalence,
grouped==onehot equivalence at the model level, and param accounting."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import analysis
from compile.config import ModelConfig, MoEConfig
from compile.layers.router import route_tokens
from compile.model import forward, init_params, num_routers
from compile.presets import get_preset


def tiny(name="t", **kw):
    base = dict(name=name, arch="mamba", n_layers=2, d_model=32,
                vocab_size=64, batch_size=2, seq_len=16)
    base.update(kw)
    return ModelConfig(**base)


def run_forward(cfg, seed=0):
    params = init_params(cfg, jax.random.PRNGKey(seed))
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
    logits, aux = forward(cfg, params, tok)
    return params, logits, aux


class TestShapes:
    @pytest.mark.parametrize("arch", ["mamba", "mamba2", "gdn", "samba", "llama"])
    def test_logits_shape(self, arch):
        cfg = tiny(arch=arch)
        _, logits, _ = run_forward(cfg)
        assert logits.shape == (2, 16, 64)
        assert np.all(np.isfinite(np.asarray(logits)))

    def test_rom_load_rows_match_num_routers(self):
        cfg = tiny(rom_targets=["conv", "gate", "out"], routing="shared",
                   rom=MoEConfig(num_experts=4))
        _, _, aux = run_forward(cfg)
        assert aux.load.shape == (num_routers(cfg), 4)

    def test_independent_routing_has_more_routers(self):
        shared = tiny(rom_targets=["conv", "gate", "out"], routing="shared",
                      rom=MoEConfig(num_experts=4))
        indep = tiny(rom_targets=["conv", "gate", "out"], routing="independent",
                     rom=MoEConfig(num_experts=4))
        assert num_routers(indep) == 3 * num_routers(shared)

    def test_load_rows_sum_to_one(self):
        cfg = tiny(rom_targets=["conv", "gate", "out"], routing="shared",
                   rom=MoEConfig(num_experts=4))
        _, _, aux = run_forward(cfg)
        np.testing.assert_allclose(np.asarray(aux.load).sum(axis=1), 1.0,
                                   rtol=1e-5)


class TestEquivalences:
    def test_single_expert_rom_equals_dense(self):
        """RoM with E=1 must be numerically a dense Mamba (same seed)."""
        dense = tiny()
        rom1 = tiny(rom_targets=[], rom=MoEConfig(num_experts=1))
        p_d, l_d, _ = run_forward(dense)
        p_r, l_r, _ = run_forward(rom1)
        np.testing.assert_allclose(np.asarray(l_d), np.asarray(l_r), rtol=1e-6)

    def test_grouped_matches_onehot_model_level(self):
        """The megablocks path and the one-hot oracle agree through a whole
        forward (shared params, same routing)."""
        kw = dict(rom_targets=["conv", "gate", "out"], routing="shared",
                  rom=MoEConfig(num_experts=4))
        c1 = tiny(moe_impl="onehot", **kw)
        c2 = tiny(moe_impl="grouped", **kw)
        params = init_params(c1, jax.random.PRNGKey(0))
        tok = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 64)
        l1, _ = forward(c1, params, tok)
        l2, _ = forward(c2, params, tok)
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                                   rtol=5e-4, atol=5e-4)

    def test_scan_impls_agree(self):
        params = init_params(tiny(), jax.random.PRNGKey(0))
        tok = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 64)
        outs = []
        for impl in ("loop", "assoc", "pallas"):
            cfg = tiny(scan_impl=impl)
            outs.append(np.asarray(forward(cfg, params, tok)[0]))
        np.testing.assert_allclose(outs[0], outs[1], rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(outs[0], outs[2], rtol=2e-4, atol=2e-4)


class TestRouting:
    def test_shared_decision_identical_across_banks(self):
        """The defining invariant of RoM (Eq. 9-11): with shared routing the
        same top-K indicator drives every bank. We verify via route_tokens
        determinism: same inputs + same router weights => same decision."""
        x = jax.random.normal(jax.random.PRNGKey(0), (64, 32))
        wr = jax.random.normal(jax.random.PRNGKey(1), (32, 8))
        r1 = route_tokens(x, wr, top_k=1)
        r2 = route_tokens(x, wr, top_k=1)
        np.testing.assert_array_equal(np.asarray(r1.route), np.asarray(r2.route))

    def test_gates_are_probabilities(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (64, 32))
        wr = jax.random.normal(jax.random.PRNGKey(1), (32, 8))
        r = route_tokens(x, wr, top_k=2)
        g = np.asarray(r.gates)
        assert np.all(g >= 0) and np.all(g <= 1)
        # top-1 gate >= top-2 gate
        assert np.all(g[:, 0] >= g[:, 1])

    def test_balance_loss_bounds(self):
        # N * sum f_e p_e == 1 exactly when both are uniform; >= 1 otherwise.
        x = jax.random.normal(jax.random.PRNGKey(0), (512, 32))
        wr = jax.random.normal(jax.random.PRNGKey(1), (32, 8)) * 0.01
        r = route_tokens(x, wr, top_k=1)
        assert float(r.balance) >= 0.98  # ~1 for near-uniform routing

    def test_jitter_changes_routing(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (256, 32))
        wr = jax.random.normal(jax.random.PRNGKey(1), (32, 8)) * 0.05
        r0 = route_tokens(x, wr, top_k=1)
        r1 = route_tokens(x, wr, top_k=1, jitter=0.5, key=jax.random.PRNGKey(7))
        assert np.any(np.asarray(r0.route) != np.asarray(r1.route))


class TestAnalysis:
    def test_rom_total_exceeds_active(self):
        cfg = tiny(rom_targets=["conv", "gate", "out"], routing="shared",
                   rom=MoEConfig(num_experts=8))
        total, active = analysis.param_counts(cfg)
        dense_total, dense_active = analysis.param_counts(tiny())
        assert total > 2 * active  # 8 experts on the 3 big banks
        # Active params ~= dense + router (same compute per token).
        assert abs(active - dense_active) < 0.05 * dense_active + 8 * 32 * 2 * 2

    def test_dense_total_equals_active(self):
        total, active = analysis.param_counts(tiny())
        assert total == active

    def test_flops_monotonic_in_experts_only_for_total(self):
        dense = tiny()
        rom = tiny(rom_targets=["conv", "gate", "out"], routing="shared",
                   rom=MoEConfig(num_experts=8))
        f_d = analysis.flops_per_token(dense, 16)
        f_r = analysis.flops_per_token(rom, 16)
        # top-1 RoM adds only router FLOPS.
        assert f_r < 1.1 * f_d

    def test_samba_e4_more_flops_than_e2(self):
        e2 = get_preset("samba-e2")
        e4 = get_preset("samba-e4")
        assert analysis.flops_per_token(e4, 128) > 1.2 * analysis.flops_per_token(e2, 128)

    def test_rom_flops_saving_vs_expand4(self):
        """Table 1 headline: RoM on e=2 ~ e=4 quality at ~23% fewer FLOPS.
        Here we pin the FLOPS relation the claim rests on."""
        e4 = get_preset("samba-e4")
        rom2 = get_preset("samba-e2-rom")
        f4 = analysis.flops_per_token(e4, 128)
        fr = analysis.flops_per_token(rom2, 128)
        assert fr < 0.9 * f4  # RoM(e=2) strictly cheaper than dense e=4
