"""Layer-level invariants: attention masks/RoPE, Mamba2 SSD scan vs naive
loop, GDN delta-rule vs naive loop, MoE bank semantics, MLP sharing."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.config import ModelConfig, MoEConfig
from compile.layers.attention import attn_block, init_attn_block, rope
from compile.layers.gdn import _delta_scan, gdn_block, init_gdn_block
from compile.layers.mamba2 import _ssd_scan, init_mamba2_block, mamba2_block
from compile.layers.mlp import init_mlp_block, mlp_block
from compile.layers.moe_linear import bank_apply, bank_shape
from compile.layers.router import Routing, _topk, route_tokens


def cfg(**kw):
    base = dict(name="t", arch="samba", n_layers=1, d_model=32, vocab_size=64,
                n_heads=4, window=8)
    base.update(kw)
    return ModelConfig(**base)


class TestRope:
    def test_preserves_norm(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (2, 4, 16, 8))
        y = rope(x)
        np.testing.assert_allclose(
            np.linalg.norm(np.asarray(x), axis=-1),
            np.linalg.norm(np.asarray(y), axis=-1),
            rtol=1e-5,
        )

    def test_position_zero_identity(self):
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 2, 4, 8))
        y = rope(x)
        np.testing.assert_allclose(np.asarray(x)[:, :, 0], np.asarray(y)[:, :, 0],
                                   rtol=1e-6)

    def test_relative_property(self):
        # <rope(q,i), rope(k,j)> depends only on i-j: shift both positions.
        q = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 8, 8))
        k = jax.random.normal(jax.random.PRNGKey(3), (1, 1, 8, 8))
        qr, kr = rope(q), rope(k)
        dots = np.einsum("bhtd,bhsd->ts", np.asarray(qr), np.asarray(kr))
        # compare (2,0) with (5,3): same offset 2, same q/k content requires
        # constant q,k across positions:
        qc = jnp.broadcast_to(q[:, :, :1], q.shape)
        kc = jnp.broadcast_to(k[:, :, :1], k.shape)
        d = np.einsum("bhtd,bhsd->ts", np.asarray(rope(qc)), np.asarray(rope(kc)))
        np.testing.assert_allclose(d[2, 0], d[5, 3], rtol=1e-4)
        del dots


class TestAttention:
    def test_causality(self):
        c = cfg(window=0)
        p = init_attn_block(c, jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 12, 32))
        y0, _ = attn_block(c, p, x, window=None)
        x2 = x.at[:, 8:].set(9.0)
        y2, _ = attn_block(c, p, x2, window=None)
        np.testing.assert_allclose(np.asarray(y0)[:, :8], np.asarray(y2)[:, :8],
                                   rtol=1e-4, atol=1e-5)

    def test_sliding_window_limits_reach(self):
        c = cfg()
        p = init_attn_block(c, jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 24, 32))
        y0, _ = attn_block(c, p, x, window=4)
        # Perturb a token > window before the last position.
        x2 = x.at[:, 5].set(7.0)
        y2, _ = attn_block(c, p, x2, window=4)
        np.testing.assert_allclose(np.asarray(y0)[:, 20:], np.asarray(y2)[:, 20:],
                                   rtol=1e-4, atol=1e-5)

    @pytest.mark.parametrize("mode,banks", [("moa", ("q", "o")),
                                            ("switchhead", ("v", "o"))])
    def test_attn_moe_param_shapes(self, mode, banks):
        c = cfg(attn_moe=mode, attn_moe_experts=4)
        p = init_attn_block(c, jax.random.PRNGKey(0))
        for b in ("q", "k", "v", "o"):
            expect_e = 4 if b in banks else 1
            assert p[f"w_{b}"].shape == bank_shape(expect_e, 32, 32)
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 32))
        y, stats = attn_block(c, p, x, window=8)
        assert y.shape == x.shape
        assert len(stats) == 1


class TestMamba2:
    def test_ssd_scan_matches_naive(self):
        k = jax.random.split(jax.random.PRNGKey(0), 5)
        Bz, T, H, P, N = 2, 12, 2, 4, 3
        x = jax.random.normal(k[0], (Bz, T, H, P))
        dt = jax.nn.softplus(jax.random.normal(k[1], (Bz, T, H)))
        a = -jnp.exp(jax.random.normal(k[2], (H,)))
        Bm = jax.random.normal(k[3], (Bz, T, N))
        Cm = jax.random.normal(k[4], (Bz, T, N))
        fast = _ssd_scan(x, dt, a, Bm, Cm, chunk=4)
        # Naive per-step recurrence.
        h = np.zeros((Bz, H, P, N))
        outs = []
        xn, dtn, Bn, Cn = map(np.asarray, (x, dt, Bm, Cm))
        an = np.asarray(a)
        for t in range(T):
            decay = np.exp(dtn[:, t] * an)[:, :, None, None]
            inc = np.einsum("bh,bhp,bn->bhpn", dtn[:, t], xn[:, t], Bn[:, t])
            h = decay * h + inc
            outs.append(np.einsum("bhpn,bn->bhp", h, Cn[:, t]))
        naive = np.stack(outs, axis=1)
        np.testing.assert_allclose(np.asarray(fast), naive, rtol=1e-4, atol=1e-4)

    def test_block_shapes_and_rom(self):
        c = cfg(arch="mamba2", rom=MoEConfig(num_experts=4))
        p = init_mamba2_block(c, jax.random.PRNGKey(0))
        assert p["w_in"].shape[0] == 4
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32))
        y, r, stats = mamba2_block(c, p, x)
        assert y.shape == x.shape
        assert r is not None and len(stats) == 1


class TestGDN:
    def test_delta_scan_matches_naive(self):
        k = jax.random.split(jax.random.PRNGKey(0), 5)
        B, T, H, Dk = 1, 10, 2, 3
        q = jax.random.normal(k[0], (B, T, H, Dk))
        kk = jax.random.normal(k[1], (B, T, H, Dk))
        v = jax.random.normal(k[2], (B, T, H, Dk))
        alpha = jax.nn.sigmoid(jax.random.normal(k[3], (B, T, H)))
        beta = jax.nn.sigmoid(jax.random.normal(k[4], (B, T, H)))
        fast = _delta_scan(q, kk, v, alpha, beta)
        S = np.zeros((B, H, Dk, Dk))
        outs = []
        qn, kn, vn, an, bn = map(np.asarray, (q, kk, v, alpha, beta))
        for t in range(T):
            Sk = np.einsum("bhmn,bhn->bhm", S, kn[:, t])
            delta = vn[:, t] - Sk
            S = an[:, t][..., None, None] * (
                S + bn[:, t][..., None, None]
                * np.einsum("bhm,bhn->bhmn", delta, kn[:, t]))
            outs.append(np.einsum("bhmn,bhn->bhm", S, qn[:, t]))
        naive = np.stack(outs, axis=1)
        np.testing.assert_allclose(np.asarray(fast), naive, rtol=1e-4, atol=1e-4)

    def test_block_runs_with_rom(self):
        c = cfg(arch="gdn", rom=MoEConfig(num_experts=4))
        p = init_gdn_block(c, jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32))
        y, r, stats = gdn_block(c, p, x)
        assert y.shape == x.shape and r is not None


class TestBankAndRouter:
    def test_topk_matches_lax(self):
        probs = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(0), (32, 8)))
        for k in (1, 2, 3):
            g_ours, i_ours = _topk(probs, k)
            g_lax, i_lax = jax.lax.top_k(probs, k)
            np.testing.assert_allclose(np.asarray(g_ours), np.asarray(g_lax),
                                       rtol=1e-6)
            np.testing.assert_array_equal(np.asarray(i_ours), np.asarray(i_lax))

    def test_bank_apply_dense_equals_expert1(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (16, 8))
        w = jax.random.normal(jax.random.PRNGKey(1), (8, 12))
        np.testing.assert_allclose(
            np.asarray(bank_apply(x, w, None)),
            np.asarray(bank_apply(x, w[None], None)),
            rtol=1e-6,
        )

    def test_bank_topk2_sums_experts(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (16, 8))
        w = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 12))
        wr = jax.random.normal(jax.random.PRNGKey(2), (8, 4))
        r = route_tokens(x, wr, top_k=2)
        y = bank_apply(x, w, r)
        manual = np.stack([
            np.asarray(x[i] @ w[int(r.route[i, 0])]) + np.asarray(x[i] @ w[int(r.route[i, 1])])
            for i in range(16)
        ])
        np.testing.assert_allclose(np.asarray(y), manual, rtol=1e-4, atol=1e-5)


class TestMLP:
    def test_ffn_moe_shared_router_has_no_router_param(self):
        c = cfg(ffn_moe=MoEConfig(num_experts=4), ffn_moe_share_router=True)
        p = init_mlp_block(c, jax.random.PRNGKey(0))
        assert "router" not in p

    def test_inherited_routing_used(self):
        c = cfg(ffn_moe=MoEConfig(num_experts=4), ffn_moe_share_router=True)
        p = init_mlp_block(c, jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 32))
        wr = jax.random.normal(jax.random.PRNGKey(2), (32, 4))
        r = route_tokens(x.reshape(8, 32), wr, top_k=1)
        y1, _ = mlp_block(c, p, x, inherited=r)
        # A different inherited decision changes the output.
        r2 = Routing(route=(r.route + 1) % 4, gates=r.gates, load=r.load,
                     balance=r.balance)
        y2, _ = mlp_block(c, p, x, inherited=r2)
        assert not np.allclose(np.asarray(y1), np.asarray(y2))
