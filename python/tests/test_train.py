"""Training-step semantics: loss decreases on an overfit batch, grad-accum
path == fused path, AdamW math, eval artifact counting."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from compile import train
from compile.config import ModelConfig, MoEConfig
from compile.model import init_params


def tiny(**kw):
    base = dict(name="t", arch="mamba", n_layers=2, d_model=32, vocab_size=64,
                batch_size=2, seq_len=16)
    base.update(kw)
    return ModelConfig(**base)


def fresh_state(cfg, seed=0):
    params = jax.jit(train.make_init_fn(cfg))(jnp.asarray(seed, jnp.int32))
    m = jax.tree_util.tree_map(jnp.zeros_like, params)
    v = jax.tree_util.tree_map(jnp.zeros_like, params)
    return params, m, v


def test_loss_decreases_overfit():
    cfg = tiny()
    params, m, v = fresh_state(cfg)
    step = jax.jit(train.make_step_fn(cfg))
    tok = jax.random.randint(jax.random.PRNGKey(0), (2, 16), 0, 64)
    losses = []
    for s in range(1, 26):
        params, m, v, loss, _ = step(params, m, v, jnp.asarray(float(s)),
                                     jnp.asarray(3e-3), tok, tok)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 1.0, losses


def test_rom_loss_decreases_overfit():
    cfg = tiny(rom_targets=["conv", "gate", "out"], routing="shared",
               rom=MoEConfig(num_experts=4))
    params, m, v = fresh_state(cfg)
    step = jax.jit(train.make_step_fn(cfg))
    tok = jax.random.randint(jax.random.PRNGKey(0), (2, 16), 0, 64)
    losses = []
    for s in range(1, 26):
        params, m, v, loss, _ = step(params, m, v, jnp.asarray(float(s)),
                                     jnp.asarray(3e-3), tok, tok)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 1.0, losses


def test_grad_accum_matches_fused():
    """grad over two microbatches + apply == fused step over the full batch."""
    cfg = tiny(batch_size=4)
    params, m, v = fresh_state(cfg)
    tok = jax.random.randint(jax.random.PRNGKey(0), (4, 16), 0, 64)
    tgt = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, 64)

    step = jax.jit(train.make_step_fn(cfg))
    p_f, m_f, v_f, loss_f, load_f = step(params, m, v, jnp.asarray(1.0),
                                         jnp.asarray(1e-3), tok, tgt)

    grad = jax.jit(train.make_grad_fn(cfg))
    apply = jax.jit(train.make_apply_fn(cfg))
    gacc = jax.tree_util.tree_map(jnp.zeros_like, params)
    gacc, l1, load1 = grad(params, gacc, tok[:2], tgt[:2])
    gacc, l2, _load2 = grad(params, gacc, tok[2:], tgt[2:])
    # grad's telemetry output mirrors step's (R, E) dispatch-fraction shape,
    # so the rust session can decode either program's load identically.
    assert np.asarray(load1).shape == np.asarray(load_f).shape
    p_a, m_a, v_a = apply(params, m, v, gacc, jnp.asarray(1.0),
                          jnp.asarray(1e-3), jnp.asarray(2.0))

    np.testing.assert_allclose(float(loss_f), (float(l1) + float(l2)) / 2,
                               rtol=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(p_f),
                    jax.tree_util.tree_leaves(p_a)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-4, atol=1e-6)


def test_grad_emits_router_load():
    """The grad artifact's new trailing output: per-router dispatch
    fractions, same semantics as the fused step's load output."""
    cfg = tiny(rom_targets=["conv", "gate", "out"], routing="shared",
               rom=MoEConfig(num_experts=4))
    params, _, _ = fresh_state(cfg)
    grad = jax.jit(train.make_grad_fn(cfg))
    gacc = jax.tree_util.tree_map(jnp.zeros_like, params)
    tok = jax.random.randint(jax.random.PRNGKey(0), (2, 16), 0, 64)
    _, loss, load = grad(params, gacc, tok, tok)
    load = np.asarray(load)
    assert load.ndim == 2 and load.shape[1] == 4
    np.testing.assert_allclose(load.sum(axis=-1), np.ones(load.shape[0]),
                               rtol=1e-5)
    assert float(loss) > 0


def test_adamw_step_math():
    """One AdamW update against a hand-computed value."""
    p = {"w": jnp.asarray([1.0, -2.0])}
    m = {"w": jnp.zeros(2)}
    v = {"w": jnp.zeros(2)}
    g = {"w": jnp.asarray([0.5, 0.5])}
    lr = 0.1
    p2, m2, v2 = train.adamw_update(p, m, v, g, jnp.asarray(1.0), lr)
    # step 1: mhat = g, vhat = g^2 -> update = g/|g| = 1
    expect = np.asarray([1.0, -2.0]) - lr * (
        np.asarray([1.0, 1.0]) * np.sign([0.5, 0.5])
        + train.WEIGHT_DECAY * np.asarray([1.0, -2.0]))
    np.testing.assert_allclose(np.asarray(p2["w"]), expect, rtol=1e-4)


def test_clip_by_global_norm():
    g = {"a": jnp.asarray([3.0, 4.0])}  # norm 5
    clipped = train._clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(np.asarray(clipped["a"]), [0.6, 0.8], rtol=1e-5)
    # Below the threshold: untouched.
    same = train._clip_by_global_norm(g, 10.0)
    np.testing.assert_allclose(np.asarray(same["a"]), [3.0, 4.0], rtol=1e-6)


def test_eval_counts_tokens():
    cfg = tiny()
    params, _, _ = fresh_state(cfg)
    ev = jax.jit(train.make_eval_fn(cfg))
    tok = jax.random.randint(jax.random.PRNGKey(0), (1, 16), 0, 64)
    nll, count = ev(params, tok, tok)
    assert float(count) == 16.0
    assert float(nll) > 0


def test_eval_matches_step_loss_at_init():
    cfg = tiny()
    params, m, v = fresh_state(cfg)
    tok = jax.random.randint(jax.random.PRNGKey(0), (1, 16), 0, 64)
    tgt = jax.random.randint(jax.random.PRNGKey(1), (1, 16), 0, 64)
    nll, count = jax.jit(train.make_eval_fn(cfg))(params, tok, tgt)
    # step reports the pre-update loss on the same batch
    cfg1 = dataclasses.replace(cfg, batch_size=1)
    _, _, _, loss, _ = jax.jit(train.make_step_fn(cfg1))(
        params, m, v, jnp.asarray(1.0), jnp.asarray(0.0), tok, tgt)
    np.testing.assert_allclose(float(nll) / float(count), float(loss), rtol=1e-5)


def test_balance_loss_changes_total_grad():
    """With balance_loss on, the router weights receive an extra gradient."""
    cfg0 = tiny(rom_targets=["conv", "gate", "out"], routing="shared",
                rom=MoEConfig(num_experts=4, balance_loss=0.0))
    cfg1 = tiny(rom_targets=["conv", "gate", "out"], routing="shared",
                rom=MoEConfig(num_experts=4, balance_loss=1.0))
    params = init_params(cfg0, jax.random.PRNGKey(0))
    tok = jax.random.randint(jax.random.PRNGKey(0), (2, 16), 0, 64)

    def router_grad(cfg):
        g = jax.grad(lambda p: train.loss_fn(cfg, p, tok, tok)[0])(params)
        return np.asarray(g["blocks"][0]["router"])

    assert not np.allclose(router_grad(cfg0), router_grad(cfg1))
