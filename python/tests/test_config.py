"""Config (de)serialization + preset integrity."""

import json

import pytest

from compile.config import ModelConfig, MoEConfig
from compile.presets import all_presets, get_preset


def test_roundtrip():
    cfg = ModelConfig(name="x", arch="samba", rom_targets=["conv", "out"],
                      rom=MoEConfig(num_experts=8))
    cfg2 = ModelConfig.from_json(cfg.to_json())
    assert cfg == cfg2


def test_dt_rank_default():
    cfg = ModelConfig(d_model=64)
    assert cfg.dt_rank == 4
    cfg = ModelConfig(d_model=256)
    assert cfg.dt_rank == 16  # paper: d_r = d_m / 16


def test_rejects_bad_arch():
    with pytest.raises(ValueError):
        ModelConfig(arch="transformer")
    with pytest.raises(ValueError):
        ModelConfig(routing="magic")
    with pytest.raises(ValueError):
        ModelConfig(rom_targets=["zap"], rom=MoEConfig(num_experts=8))


def test_rom_targets_require_experts():
    with pytest.raises(ValueError):
        ModelConfig(rom_targets=["conv"])  # default num_experts == 1


def test_block_layouts():
    assert ModelConfig(arch="mamba", n_layers=3).block_layout() == ["mamba"] * 3
    assert ModelConfig(arch="samba", n_layers=2).block_layout() == [
        "mamba", "swa", "mlp", "mamba", "swa", "mlp"]
    assert ModelConfig(arch="llama", n_layers=2).block_layout() == [
        "swa", "mlp", "swa", "mlp"]


def test_presets_build_and_are_unique():
    presets = all_presets()
    assert len(presets) > 25
    names = [c.name for c in presets.values()]
    assert len(set(names)) == len(names)
    for name, cfg in presets.items():
        assert name == cfg.name
        # Every preset must serialize through plain JSON.
        doc = json.loads(cfg.to_json())
        assert ModelConfig.from_dict(doc) == cfg


def test_get_preset_unknown():
    with pytest.raises(KeyError):
        get_preset("nope")
