//! Integration: load every artifact bundle, execute init/step/eval, and
//! cross-check the fused-step losses against the python-recorded golden
//! values (artifacts/<name>/golden.json).
//!
//! Requires `make artifacts` (tests skip politely when artifacts are absent).

use std::sync::Arc;

use rom::runtime::artifact::Bundle;
use rom::runtime::session::Session;
use rom::runtime::tensor::Tensor;
use rom::substrate::rng::Rng;

fn artifacts_root() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have(name: &str) -> bool {
    artifacts_root().join(name).join("manifest.json").exists()
}

fn rand_batch(rng: &mut Rng, b: usize, t: usize, vocab: usize) -> Tensor {
    let data: Vec<i32> = (0..b * t).map(|_| rng.below(vocab as u64) as i32).collect();
    Tensor::i32(&[b, t], data)
}

#[test]
fn init_step_eval_roundtrip() {
    if !have("rom-tiny") {
        eprintln!("skipping: artifacts/rom-tiny missing (run `make artifacts`)");
        return;
    }
    let bundle = Bundle::open(artifacts_root().join("rom-tiny")).unwrap();
    let man = &bundle.manifest;
    assert!(man.num_leaves() > 0);
    assert_eq!(man.num_experts, 8);

    let mut sess = Session::init(Arc::clone(&bundle), 0).unwrap();
    let mut rng = Rng::new(7);
    let tok = rand_batch(&mut rng, man.batch_size, man.seq_len, man.vocab_size);
    let tgt = rand_batch(&mut rng, man.batch_size, man.seq_len, man.vocab_size);

    let out1 = sess.train_step(4e-4, &tok, &tgt).unwrap();
    assert!(out1.loss.is_finite() && out1.loss > 0.0, "loss {}", out1.loss);
    // The Tensor-path train_step always decodes router telemetry.
    let load = out1.router_load.as_ref().expect("train_step decodes router load");
    assert_eq!(load.len(), man.num_routers * man.num_experts);
    // Each router's dispatch fractions sum to 1.
    for r in 0..man.num_routers {
        let s: f32 = load[r * man.num_experts..(r + 1) * man.num_experts].iter().sum();
        assert!((s - 1.0).abs() < 1e-3, "router {r} load sums to {s}");
    }

    // Opt-out path: skipping the telemetry decode must not change the loss
    // stream, and must report no load.
    let tok_lit = tok.to_literal().unwrap();
    let tgt_lit = tgt.to_literal().unwrap();
    let quiet = sess.train_step_device(4e-4, &tok_lit, &tgt_lit, false).unwrap();
    assert!(quiet.router_load.is_none());
    assert!(quiet.loss.is_finite());

    // Same batch again: loss must drop (the step actually updated params).
    let out2 = sess.train_step(4e-4, &tok, &tgt).unwrap();
    assert!(out2.loss < out1.loss, "loss {} -> {}", out1.loss, out2.loss);

    // Eval at the smallest artifact length.
    let len = man.eval_lens[0];
    let etok = rand_batch(&mut rng, 1, len, man.vocab_size);
    let etgt = rand_batch(&mut rng, 1, len, man.vocab_size);
    let (nll, count) = sess.eval(len, &etok, &etgt).unwrap();
    assert_eq!(count, len as f64);
    assert!(nll > 0.0);
}

#[test]
fn golden_cross_check() {
    // The decisive L2<->L3 consistency test: the rust-executed fused step must
    // reproduce the python-recorded losses bit-for-bit-ish (same HLO, same
    // inputs; tolerance covers run-to-run nondeterminism in reductions).
    for name in ["mamba-tiny", "rom-tiny"] {
        if !have(name) {
            eprintln!("skipping golden for {name}");
            continue;
        }
        let bundle = Bundle::open(artifacts_root().join(name)).unwrap();
        let Some((data_seed, lr, golden_losses)) = bundle.golden().unwrap() else {
            eprintln!("no golden.json for {name}");
            continue;
        };
        let man = bundle.manifest.clone();
        let mut sess = Session::init(Arc::clone(&bundle), 0).unwrap();
        // Reproduce numpy RandomState(data_seed).randint batches: we can't,
        // so golden.json batches use the same MT19937 stream — instead the
        // python side records its own batches implicitly; here we only check
        // the FIRST loss, which for an untrained model is data-independent to
        // ~1%: ln(V) +- small. Then we additionally check determinism of the
        // rust path itself.
        let mut rng = Rng::new(data_seed);
        let tok = rand_batch(&mut rng, man.batch_size, man.seq_len, man.vocab_size);
        let tgt = rand_batch(&mut rng, man.batch_size, man.seq_len, man.vocab_size);
        let out = sess.train_step(lr as f32, &tok, &tgt).unwrap();
        let rel = (out.loss - golden_losses[0]).abs() / golden_losses[0];
        assert!(
            rel < 0.05,
            "{name}: rust first-step loss {} vs python golden {} (rel {rel})",
            out.loss,
            golden_losses[0]
        );

        // Determinism: fresh session, same seed + batch => identical loss.
        let mut sess2 = Session::init(Arc::clone(&bundle), 0).unwrap();
        let out2 = sess2.train_step(lr as f32, &tok, &tgt).unwrap();
        assert_eq!(out.loss, out2.loss, "{name}: rust step nondeterministic");
    }
}

#[test]
fn grad_accum_matches_fused() {
    if !have("mamba-tiny") {
        eprintln!("skipping: artifacts/mamba-tiny missing");
        return;
    }
    let bundle = Bundle::open(artifacts_root().join("mamba-tiny")).unwrap();
    let man = bundle.manifest.clone();
    if man.batch_size % man.micro_batch != 0 {
        eprintln!("skipping: micro_batch does not divide batch");
        return;
    }
    let mut rng = Rng::new(3);
    let tok = rand_batch(&mut rng, man.batch_size, man.seq_len, man.vocab_size);
    let tgt = rand_batch(&mut rng, man.batch_size, man.seq_len, man.vocab_size);

    let mut fused = Session::init(Arc::clone(&bundle), 0).unwrap();
    let fused_out = fused.train_step(1e-3, &tok, &tgt).unwrap();

    // Split the batch into micro_batch-sized slices.
    let mut micro = Vec::new();
    let mb = man.micro_batch;
    let t = man.seq_len;
    for c in 0..(man.batch_size / mb) {
        let slice = |src: &Tensor| {
            let d = src.as_i32().unwrap();
            Tensor::i32(&[mb, t], d[c * mb * t..(c + 1) * mb * t].to_vec())
        };
        micro.push((slice(&tok), slice(&tgt)));
    }
    let mut accum = Session::init(Arc::clone(&bundle), 0).unwrap();
    let accum_out = accum.train_step_accum(1e-3, &micro).unwrap();
    let mean_loss = accum_out.loss;
    let rel = (mean_loss - fused_out.loss).abs() / fused_out.loss;
    assert!(rel < 1e-4, "accum loss {mean_loss} vs fused {}", fused_out.loss);
    // Router telemetry on the accum path (new grad artifacts append the load
    // output; legacy bundles report None). When present it must have the
    // same shape as the fused path's. Normalization only holds for MoE
    // variants — dense models emit the all-zero (1, 1) placeholder load.
    if let Some(load) = &accum_out.router_load {
        assert_eq!(load.len(), man.num_routers * man.num_experts);
        if man.num_experts > 1 {
            for r in 0..man.num_routers {
                let s: f32 =
                    load[r * man.num_experts..(r + 1) * man.num_experts].iter().sum();
                assert!((s - 1.0).abs() < 1e-3, "accum router {r} load sums to {s}");
            }
        }
    } else {
        eprintln!("note: grad artifact predates router-load output (legacy arity)");
    }

    // Parameters after one step must agree across the two paths.
    let (p1, _, _) = fused.export().unwrap();
    let (p2, _, _) = accum.export().unwrap();
    for (a, b) in p1.iter().zip(p2.iter()) {
        let (av, bv) = (a.as_f32().unwrap(), b.as_f32().unwrap());
        for (x, y) in av.iter().zip(bv.iter()) {
            assert!((x - y).abs() < 5e-4 + 1e-3 * x.abs(), "{x} vs {y}");
        }
    }

    // Perf guard: the grad accumulator is seeded from the persistent zero
    // literals uploaded at init, so one accum step uploads exactly the
    // microbatch encodes (2 per microbatch) plus 3 control scalars. A
    // reintroduced per-step gradient-buffer upload would add num_leaves to
    // the delta and trip this.
    let before = accum.host_uploads();
    for _ in 0..3 {
        accum.train_step_accum(1e-3, &micro).unwrap();
    }
    let delta = accum.host_uploads() - before;
    assert_eq!(
        delta as usize,
        3 * (2 * micro.len() + 3),
        "unexpected per-step uploads: accum step uploaded more than batch + scalars"
    );
}
