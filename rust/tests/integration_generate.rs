//! Generation integration: prefill + stepwise decode must reproduce the
//! full-window eval artifacts' NLL (the decode parity contract), hybrid
//! prefix+tail prompt consumption must match pure stepwise decoding,
//! generation must be deterministic across reruns and across parallel
//! sessions, and the generate coordinator's error paths must fail cleanly.
//!
//! Requires `make artifacts` (tests skip politely when artifacts are absent
//! or predate the decoding subsystem).

use std::sync::Arc;

use rom::config::TrainCfg;
use rom::coordinator::checkpoint::Checkpoint;
use rom::coordinator::generate::{argmax, generate, GenerateCfg};
use rom::coordinator::trainer::Trainer;
use rom::data::corpus::{Corpus, CorpusSpec};
use rom::experiments::scheduler::run_jobs;
use rom::runtime::artifact::Bundle;
use rom::runtime::session::Session;
use rom::runtime::tensor::Tensor;

fn artifacts_root() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have(name: &str) -> bool {
    artifacts_root().join(name).join("manifest.json").exists()
}

/// Open a bundle iff it exists AND ships generation artifacts.
fn open_decodable(name: &str) -> Option<Arc<Bundle>> {
    if !have(name) {
        eprintln!("skipping: artifacts/{name} missing (run `make artifacts`)");
        return None;
    }
    let bundle = Bundle::open(artifacts_root().join(name)).unwrap();
    if bundle.manifest.decode.is_none() {
        eprintln!("skipping: artifacts/{name} predates decode artifacts");
        return None;
    }
    Some(bundle)
}

/// Stable f64 log-softmax NLL of `target` under a logits row.
fn nll_of(logits: &[f32], target: usize) -> f64 {
    let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    let sum: f64 = logits.iter().map(|&x| (x as f64 - max).exp()).sum();
    -((logits[target] as f64 - max) - sum.ln())
}

#[test]
fn stepwise_decode_matches_eval_artifact() {
    // The acceptance parity test: summed next-token NLL from decode_step —
    // one token at a time from a zero state — must match the full-window
    // eval artifact, and the prefill artifact's last-position logits must
    // match both the stepwise path and the eval_last artifact. The list
    // spans every decode-state family: pure SSM (mamba-tiny), SSM + MoE
    // projections (rom-tiny), full attention on the capped KV cache
    // (llama), and the SSM/full-attention hybrid (hybrid).
    for name in ["mamba-tiny", "rom-tiny", "llama", "hybrid"] {
        let Some(bundle) = open_decodable(name) else { continue };
        let spec = bundle.manifest.decode.clone().unwrap();
        let man = bundle.manifest.clone();
        let sess = Session::init(Arc::clone(&bundle), 0).unwrap();
        let ctx = man.eval_lens[0];
        assert!(spec.prefill_lens.contains(&ctx), "eval lens double as prefill lens");

        let corpus = Corpus::new(CorpusSpec::default(), 17);
        let stream = corpus.generate(4242, ctx + 1);
        let (tokens, targets) = (&stream[..ctx], &stream[1..ctx + 1]);
        let tok = Tensor::i32(&[1, ctx], tokens.to_vec());
        let tgt = Tensor::i32(&[1, ctx], targets.to_vec());
        let (nll_ref, count) = sess.eval(ctx, &tok, &tgt).unwrap();
        assert_eq!(count, ctx as f64);

        // Stepwise pass: same sequence in every batch row, score row 0.
        let (bd, vocab) = (spec.batch, man.vocab_size);
        let mut state = sess.init_decode_state().unwrap();
        let mut nll_step = 0.0f64;
        let mut last_logits = Vec::new();
        for t in 0..ctx {
            let logits = sess
                .decode_step(&Tensor::i32(&[bd], vec![tokens[t]; bd]), &mut state)
                .unwrap();
            let row = logits.as_f32().unwrap()[..vocab].to_vec();
            nll_step += nll_of(&row, targets[t] as usize);
            last_logits = row;
        }
        assert_eq!(state.pos, ctx as u64);
        let rel = (nll_step - nll_ref).abs() / nll_ref.abs().max(1e-9);
        assert!(
            rel < 2e-3,
            "{name}: stepwise NLL {nll_step} vs eval {nll_ref} (rel {rel})"
        );

        // Prefill artifact: one device call over the same prompt.
        let mut flat = Vec::with_capacity(bd * ctx);
        for _ in 0..bd {
            flat.extend_from_slice(tokens);
        }
        let (plogits, pstate) =
            sess.prefill(&Tensor::i32(&[bd, ctx], flat)).unwrap();
        assert_eq!(pstate.pos, ctx as u64);
        let prow = &plogits.as_f32().unwrap()[..vocab];
        for (i, (a, b)) in prow.iter().zip(last_logits.iter()).enumerate() {
            assert!(
                (a - b).abs() < 1e-3,
                "{name}: prefill logit[{i}] {a} vs stepwise {b}"
            );
        }
        let (nll_last, _) = sess.eval_last(ctx, &tok, &tgt).unwrap();
        let nll_prefill = nll_of(prow, targets[ctx - 1] as usize);
        assert!(
            (nll_prefill - nll_last).abs() < 1e-3 * nll_last.abs().max(1.0),
            "{name}: prefill final NLL {nll_prefill} vs eval_last {nll_last}"
        );
    }
}

/// Train briefly, checkpoint, and generate — the `rom generate` pipeline.
fn checkpoint_for_generation(bundle: &Arc<Bundle>) -> std::path::PathBuf {
    let cfg = TrainCfg { steps: 5, max_lr: 3e-3, log_every: 0, ..Default::default() };
    let mut trainer = Trainer::new(Arc::clone(bundle), cfg);
    trainer.quiet = true;
    trainer.final_eval = false;
    let (_report, sess) = trainer.run_session().unwrap();
    let (params, m, v) = sess.export().unwrap();
    let dir = std::env::temp_dir().join("rom_integration_generate");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(format!("{}.ckpt", bundle.manifest.name));
    Checkpoint { step: sess.step_count(), params, m, v }.save(&path).unwrap();
    path
}

#[test]
fn hybrid_prompt_consumption_matches_pure_stepwise() {
    // A prompt longer than an artifact length is consumed hybrid: the longest
    // `prefill_L{L} <= prompt_len` prefix in one fused call, the tail via
    // decode_step. The greedy continuation must reproduce the pure stepwise
    // path token for token, and the coordinator must do exactly what the
    // session-level prefix+tail recipe does.
    let Some(bundle) = open_decodable("mamba-tiny") else { return };
    let spec = bundle.manifest.decode.clone().unwrap();
    let ckpt = checkpoint_for_generation(&bundle);
    let ck = Checkpoint::load(&ckpt).unwrap();
    let sess =
        Session::restore(Arc::clone(&bundle), &ck.params, &ck.m, &ck.v, ck.step).unwrap();

    let ctx = bundle.manifest.eval_lens[0];
    let tail = 3;
    let prompt_len = ctx + tail;
    assert!(
        !spec.prefill_lens.contains(&prompt_len),
        "tail length must force the hybrid path"
    );
    let (bd, vocab) = (spec.batch, bundle.manifest.vocab_size);
    let corpus = Corpus::new(CorpusSpec::default(), 17);
    let prompts: Vec<Vec<i32>> =
        (0..bd as u64).map(|r| corpus.generate(600 + r, prompt_len)).collect();
    let max_new = 5;
    let cfg = GenerateCfg { max_new, temperature: 0.0, top_k: 0, seed: 0 };

    // Coordinator hybrid run (greedy, so sampling is RNG-free).
    let report = generate(&sess, &prompts, &cfg).unwrap();
    assert_eq!(
        report.prefill_artifact_tokens, ctx,
        "longest artifact <= {prompt_len} is prefill_L{ctx}"
    );

    // Session-level replica of the hybrid recipe: prefill the ctx-token
    // prefix, decode_step the tail, then greedy-decode. Same ops in the same
    // order on the same device — the coordinator must match bit for bit.
    let step_toks = |ps: &[Vec<i32>], t: usize| -> Tensor {
        Tensor::i32(&[bd], ps.iter().map(|p| p[t]).collect())
    };
    let mut flat = Vec::with_capacity(bd * ctx);
    for p in &prompts {
        flat.extend_from_slice(&p[..ctx]);
    }
    let (mut logits, mut state) = sess.prefill(&Tensor::i32(&[bd, ctx], flat)).unwrap();
    for t in ctx..prompt_len {
        logits = sess.decode_step(&step_toks(&prompts, t), &mut state).unwrap();
    }
    assert_eq!(state.pos, prompt_len as u64);

    // Pure stepwise consumption of the same prompts from a zero state.
    let mut s_state = sess.init_decode_state().unwrap();
    let mut s_logits = sess.decode_step(&step_toks(&prompts, 0), &mut s_state).unwrap();
    for t in 1..prompt_len {
        s_logits = sess.decode_step(&step_toks(&prompts, t), &mut s_state).unwrap();
    }
    let (lv, sv) = (logits.as_f32().unwrap(), s_logits.as_f32().unwrap());
    for (i, (a, b)) in lv.iter().zip(sv.iter()).enumerate() {
        assert!(
            (a - b).abs() < 1e-3,
            "post-prompt logit[{i}]: hybrid {a} vs stepwise {b}"
        );
    }

    // Greedy-continue both states; the coordinator's completions must equal
    // the hybrid replica exactly AND the pure stepwise reference token for
    // token (fp drift between the parallel and sequential prefix is far
    // below the argmax margins of a trained checkpoint).
    let mut hybrid_tokens: Vec<Vec<i32>> = vec![Vec::new(); bd];
    let mut stepwise_tokens: Vec<Vec<i32>> = vec![Vec::new(); bd];
    for _ in 0..max_new {
        let (lv, sv) = (logits.as_f32().unwrap(), s_logits.as_f32().unwrap());
        let mut h_next = Vec::with_capacity(bd);
        let mut s_next = Vec::with_capacity(bd);
        for r in 0..bd {
            let h = argmax(&lv[r * vocab..(r + 1) * vocab]) as i32;
            let s = argmax(&sv[r * vocab..(r + 1) * vocab]) as i32;
            hybrid_tokens[r].push(h);
            stepwise_tokens[r].push(s);
            h_next.push(h);
            s_next.push(s);
        }
        logits = sess.decode_step(&Tensor::i32(&[bd], h_next), &mut state).unwrap();
        s_logits = sess.decode_step(&Tensor::i32(&[bd], s_next), &mut s_state).unwrap();
    }
    assert_eq!(
        report.completions, hybrid_tokens,
        "coordinator diverged from the session-level hybrid recipe"
    );
    assert_eq!(
        report.completions, stepwise_tokens,
        "hybrid consumption diverged from pure stepwise decoding"
    );

    // Exact-length prompt: the whole prompt rides the artifact.
    let exact: Vec<Vec<i32>> = prompts.iter().map(|p| p[..ctx].to_vec()).collect();
    let report = generate(&sess, &exact, &cfg).unwrap();
    assert_eq!(report.prefill_artifact_tokens, ctx);
    assert_eq!(report.prompt_len, ctx);
    let _ = std::fs::remove_file(&ckpt);
}

#[test]
fn generation_deterministic_across_runs_and_parallel_sessions() {
    let Some(bundle) = open_decodable("mamba-tiny") else { return };
    let ckpt = checkpoint_for_generation(&bundle);

    // Three prompts of a non-artifact length: exercises the decode_step
    // prompt fallback AND chunking+padding (batch is 2 for stock presets).
    let corpus = Corpus::new(CorpusSpec::default(), 17);
    let prompts: Vec<Vec<i32>> =
        (0..3).map(|i| corpus.generate(900 + i, 9)).collect();
    let cfg = GenerateCfg { max_new: 6, temperature: 0.9, top_k: 8, seed: 7 };

    let gen_once = move |ckpt: &std::path::Path, prompts: &[Vec<i32>]| {
        let bundle = Bundle::open(artifacts_root().join("mamba-tiny")).unwrap();
        let ck = Checkpoint::load(ckpt).unwrap();
        let sess =
            Session::restore(Arc::clone(&bundle), &ck.params, &ck.m, &ck.v, ck.step)
                .unwrap();
        generate(&sess, prompts, &cfg).unwrap().completions
    };

    let first = gen_once(&ckpt, &prompts);
    assert_eq!(first.len(), 3);
    assert!(first.iter().all(|c| c.len() == 6));
    let again = gen_once(&ckpt, &prompts);
    assert_eq!(first, again, "same seed + params must reproduce tokens");

    // `--jobs`-style parallel sessions: two workers, each with its own
    // client + bundle + session, must emit the identical token streams.
    let items: Vec<String> = vec!["a".into(), "b".into()];
    let ckpt2 = ckpt.clone();
    let prompts2 = prompts.clone();
    let results = run_jobs(&items, 2, move |_idx, _name| {
        Ok(gen_once(&ckpt2, &prompts2))
    });
    for r in results {
        assert_eq!(r.unwrap(), first, "parallel session diverged");
    }
    let _ = std::fs::remove_file(&ckpt);
}

#[test]
fn full_attention_long_context_ladder_is_consistent() {
    // Beyond-training-length consistency: the hybrid variant trains at
    // seq_len but evals (and decodes) up to 4x longer. Stepwise decode of
    // one long stream must reproduce the eval artifacts' summed NLL at
    // EVERY ladder rung — the per-position NLLs past the training length
    // ride KV-cache slots the training runs never touched, so drift here
    // means the position-indexed cache (not the windowed math) is wrong.
    let Some(bundle) = open_decodable("hybrid") else { return };
    let man = bundle.manifest.clone();
    let spec = man.decode.clone().unwrap();
    let cap = spec.kv_cap.expect("hybrid is a full-attention layout");
    let sess = Session::init(Arc::clone(&bundle), 0).unwrap();

    let train_len = man.seq_len;
    let rungs: Vec<usize> =
        man.eval_lens.iter().copied().filter(|&l| l <= 2 * train_len).collect();
    let longest = *rungs.last().unwrap();
    assert!(longest > train_len, "the ladder must leave the training length");
    assert!(longest <= cap, "the ladder must fit the KV cache");

    let corpus = Corpus::new(CorpusSpec::default(), 17);
    let stream = corpus.generate(7171, longest + 1);
    let (tokens, targets) = (&stream[..longest], &stream[1..longest + 1]);

    // One stepwise pass over the whole stream, accumulating per-position
    // NLL so every ladder rung reads off the same trajectory.
    let (bd, vocab) = (spec.batch, man.vocab_size);
    let mut state = sess.init_decode_state().unwrap();
    let mut nll_at = vec![0.0f64; longest];
    let mut nll = 0.0f64;
    for t in 0..longest {
        let logits = sess
            .decode_step(&Tensor::i32(&[bd], vec![tokens[t]; bd]), &mut state)
            .unwrap();
        nll += nll_of(&logits.as_f32().unwrap()[..vocab], targets[t] as usize);
        nll_at[t] = nll;
    }
    assert_eq!(state.pos, longest as u64);

    for &len in &rungs {
        let tok = Tensor::i32(&[1, len], tokens[..len].to_vec());
        let tgt = Tensor::i32(&[1, len], targets[..len].to_vec());
        let (nll_ref, count) = sess.eval(len, &tok, &tgt).unwrap();
        assert_eq!(count, len as f64);
        let nll_step = nll_at[len - 1];
        let rel = (nll_step - nll_ref).abs() / nll_ref.abs().max(1e-9);
        assert!(
            rel < 2e-3,
            "rung L{len}: stepwise NLL {nll_step} vs eval {nll_ref} (rel {rel})"
        );
    }
}

#[test]
fn full_attention_generate_is_deterministic_and_respects_kv_cap() {
    let Some(bundle) = open_decodable("llama") else { return };
    let spec = bundle.manifest.decode.clone().unwrap();
    let cap = spec.kv_cap.expect("llama is a full-attention layout");
    let sess = Session::init(Arc::clone(&bundle), 0).unwrap();
    let corpus = Corpus::new(CorpusSpec::default(), 17);

    // Sampled full-attention generation reproduces bit for bit: same seed,
    // same prompt, same tokens — the determinism contract holds on the
    // KV-cache decode path exactly as on the SSM paths.
    let prompts = vec![corpus.generate(1001, 9)];
    let cfg = GenerateCfg { max_new: 5, temperature: 0.9, top_k: 8, seed: 7 };
    let first = generate(&sess, &prompts, &cfg).unwrap().completions;
    assert_eq!(first[0].len(), 5);
    let again = generate(&sess, &prompts, &cfg).unwrap().completions;
    assert_eq!(first, again, "full-attention generation must be reproducible");

    // A request that would outrun the cache is refused upfront with a
    // clean, actionable error — no device work, no clamped cache writes.
    let long = vec![corpus.generate(1002, cap - 3)];
    let cfg = GenerateCfg { max_new: 8, temperature: 0.0, top_k: 0, seed: 0 };
    let err = generate(&sess, &long, &cfg).unwrap_err();
    assert!(
        err.to_string().contains("KV cache capacity"),
        "got: {err:#}"
    );
    // The same prompt with a max_new that fits is admitted: the boundary
    // is exact, not fuzzy. (prompt + max_new - 1 == cap uses the last slot.)
    let cfg = GenerateCfg { max_new: 4, temperature: 0.0, top_k: 0, seed: 0 };
    let report = generate(&sess, &long, &cfg).unwrap();
    assert_eq!(report.completions[0].len(), 4);
}

#[test]
fn generate_error_paths_are_clean() {
    let Some(bundle) = open_decodable("mamba-tiny") else { return };
    let sess = Session::init(Arc::clone(&bundle), 0).unwrap();
    let cfg = GenerateCfg::default();
    let ok_prompt = vec![vec![1, 2, 3]];

    let err = generate(&sess, &[], &cfg).unwrap_err();
    assert!(err.to_string().contains("no prompts"), "got: {err:#}");

    let err = generate(&sess, &[vec![]], &cfg).unwrap_err();
    assert!(err.to_string().contains("empty prompt"), "got: {err:#}");

    let err = generate(
        &sess,
        &ok_prompt,
        &GenerateCfg { max_new: 0, ..GenerateCfg::default() },
    )
    .unwrap_err();
    assert!(err.to_string().contains("max-new"), "got: {err:#}");

    let err =
        generate(&sess, &[vec![1, 2, 3], vec![4, 5]], &cfg).unwrap_err();
    assert!(err.to_string().contains("ragged"), "got: {err:#}");

    let vocab = bundle.manifest.vocab_size as i32;
    let err = generate(&sess, &[vec![1, vocab]], &cfg).unwrap_err();
    assert!(err.to_string().contains("vocabulary"), "got: {err:#}");

    // Wrong-shape session entry points bail instead of panicking.
    let err = sess.prefill(&Tensor::i32(&[1, 7], vec![0; 7])).unwrap_err();
    assert!(err.to_string().contains("prefill tokens"), "got: {err:#}");
    let spec = bundle.manifest.decode.as_ref().unwrap();
    let err = sess
        .prefill(&Tensor::i32(&[spec.batch, 7], vec![0; spec.batch * 7]))
        .unwrap_err();
    assert!(err.to_string().contains("no prefill artifact"), "got: {err:#}");

    // Unknown variant: a clean open error, long before any device work.
    assert!(Bundle::open(artifacts_root().join("no-such-variant-xyz")).is_err());
}
