//! Self-test of the `rom analyze` passes against the real tree.
//!
//! Two halves:
//!
//! * the tree as committed must be CLEAN — golden manifests satisfy the
//!   contract, the bench field universe matches EXPERIMENTS.md, the lint
//!   finds nothing;
//! * seeded corruption must be DETECTED with a useful file/line — a
//!   mutated state shape, a dropped/fractional field, an unknowable decode
//!   status, a stale decode_unsupported reason, a missing/fractional/lying
//!   `decode.kv_cap`, a params/total mismatch, a drifted schema row, a
//!   smuggled `.unwrap()` / bare spawn / uncommented `unsafe` / direct
//!   bench write.
//!
//! The corruption fixtures live in string literals, which the lint strips
//! before matching — so this file itself stays clean under `lint_tree`.

use rom::analysis::{contract, lint, repo_root, schema};

fn golden_text(name: &str) -> (String, String) {
    let path = repo_root()
        .join("rust/tests/golden")
        .join(format!("{name}.manifest.json"));
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("missing golden fixture {}: {e}", path.display()));
    (path.display().to_string(), text)
}

// ---------------------------------------------------------------------------
// Clean tree
// ---------------------------------------------------------------------------

#[test]
fn golden_manifests_satisfy_the_contract() {
    let goldens = contract::golden_manifests(&repo_root());
    assert!(
        goldens.len() >= 4,
        "expected the committed mamba/samba/llama/hybrid fixtures, found {goldens:?}"
    );
    for p in &goldens {
        let f = contract::check_manifest_file(p);
        assert!(f.is_empty(), "{} has findings: {f:#?}", p.display());
    }
}

#[test]
fn bench_schema_matches_experiments_doc() {
    let f = schema::check_tree(&repo_root());
    assert!(f.is_empty(), "schema drift: {f:#?}");
}

#[test]
fn source_lint_is_clean_on_the_tree() {
    let f = lint::lint_tree(&repo_root());
    assert!(f.is_empty(), "lint findings: {f:#?}");
}

// ---------------------------------------------------------------------------
// Seeded corruption: manifest contract
// ---------------------------------------------------------------------------

#[test]
fn mutated_state_shape_is_detected_with_line() {
    let (label, text) = golden_text("rom-tiny");
    // decode.state[1] (blocks.0.conv) shape [2, 3, 128] -> [2, 4, 128]; the
    // 5-space indent is unique to state shapes, so this hits the first leaf.
    let bad = text.replacen("\n     3,\n     128", "\n     4,\n     128", 1);
    assert_ne!(bad, text, "mutation anchor not found");
    let f = contract::check_manifest_bytes(&label, bad.as_bytes());
    let hit = f
        .iter()
        .find(|f| f.rule == "contract/state-mirror")
        .unwrap_or_else(|| panic!("no state-mirror finding in {f:#?}"));
    assert!(hit.message.contains("decode.state[1]"), "{hit}");
    assert!(
        (30..=50).contains(&hit.line),
        "finding should point into the decode.state block, got {hit}"
    );
}

#[test]
fn dropped_required_field_is_detected() {
    let (label, text) = golden_text("rom-tiny");
    let bad = text.replacen(" \"batch_size\": 8,\n", "", 1);
    assert_ne!(bad, text);
    let f = contract::check_manifest_bytes(&label, bad.as_bytes());
    assert!(
        f.iter().any(|f| f.rule == "contract/field" && f.message.contains("batch_size")),
        "{f:#?}"
    );
}

#[test]
fn fractional_count_is_detected_not_truncated() {
    let (label, text) = golden_text("rom-tiny");
    let bad = text.replacen(" \"batch_size\": 8,", " \"batch_size\": 8.5,", 1);
    assert_ne!(bad, text);
    let f = contract::check_manifest_bytes(&label, bad.as_bytes());
    let hit = f
        .iter()
        .find(|f| f.message.contains("integer-valued"))
        .unwrap_or_else(|| panic!("no truncation finding in {f:#?}"));
    assert_eq!(hit.line, 22, "top-level batch_size sits on line 22: {hit}");
}

/// Drop the llama golden's decode section (object -> null), leaving
/// `decode_unsupported` untouched — the shared setup for the decode-status
/// corruption pair below.
fn llama_without_decode() -> (String, String) {
    let (label, text) = golden_text("llama");
    let start = text.find("\"decode\": {").expect("decode anchor");
    let end = text.find("\n \"decode_unsupported\"").expect("decode_unsupported anchor");
    let mut bad = text;
    bad.replace_range(start..end, "\"decode\": null,");
    (label, bad)
}

#[test]
fn unknowable_decode_status_is_detected() {
    // decode null while decode_unsupported stays null: unknowable.
    let (label, bad) = llama_without_decode();
    let f = contract::check_manifest_bytes(&label, bad.as_bytes());
    assert!(
        f.iter().any(|f| f.rule == "contract/decode" && f.message.contains("both null")),
        "{f:#?}"
    );
}

#[test]
fn stale_decode_unsupported_reason_is_detected() {
    // A pre-kv_cap manifest claiming full attention cannot decode: the
    // emitter decodes every preset layout now, so the reason is stale by
    // construction and must be flagged, not trusted.
    let (label, bad) = llama_without_decode();
    let bad = bad.replacen(
        "\"decode_unsupported\": null,",
        "\"decode_unsupported\": \"swa block with window <= 0 has no fixed-shape state\",",
        1,
    );
    let f = contract::check_manifest_bytes(&label, bad.as_bytes());
    assert!(
        f.iter().any(|f| f.rule == "contract/decode"
            && f.message.contains("decodes every preset layout")),
        "{f:#?}"
    );
}

// ---------------------------------------------------------------------------
// Seeded corruption: decode.kv_cap (full-attention KV-cache capacity)
// ---------------------------------------------------------------------------

#[test]
fn missing_kv_cap_is_detected() {
    let (label, text) = golden_text("llama");
    let bad = text.replacen("  \"kv_cap\": 1024,\n", "", 1);
    assert_ne!(bad, text, "mutation anchor not found");
    let f = contract::check_manifest_bytes(&label, bad.as_bytes());
    let hit = f
        .iter()
        .find(|f| f.rule == "contract/decode" && f.message.contains("missing for full-attention"))
        .unwrap_or_else(|| panic!("no missing-kv_cap finding in {f:#?}"));
    assert!(hit.file.ends_with("llama.manifest.json"), "{hit}");
    assert_eq!(hit.line, 23, "with the key gone, the finding falls back to the decode opener: {hit}");
}

#[test]
fn fractional_kv_cap_is_detected_not_truncated() {
    let (label, text) = golden_text("llama");
    let bad = text.replacen("\"kv_cap\": 1024,", "\"kv_cap\": 1024.5,", 1);
    assert_ne!(bad, text);
    let f = contract::check_manifest_bytes(&label, bad.as_bytes());
    let hit = f
        .iter()
        .find(|f| f.message.contains("decode.kv_cap") && f.message.contains("integer-valued"))
        .unwrap_or_else(|| panic!("no fractional-kv_cap finding in {f:#?}"));
    assert_eq!(hit.line, 25, "decode.kv_cap sits on line 25 of the llama golden: {hit}");
}

#[test]
fn kv_cap_disagreeing_with_cache_shapes_is_detected() {
    // 512 is a plausible-looking power of two, but it contradicts BOTH the
    // ModelCfg derivation (2 * max(seq 128, evals 128/256/512) = 1024) and
    // the cache leaves' capacity dim — each lie gets its own finding.
    let (label, text) = golden_text("llama");
    let bad = text.replacen("\"kv_cap\": 1024,", "\"kv_cap\": 512,", 1);
    assert_ne!(bad, text);
    let f = contract::check_manifest_bytes(&label, bad.as_bytes());
    let derive = f
        .iter()
        .find(|f| f.message.contains("ModelCfg::kv_cap derives 1024"))
        .unwrap_or_else(|| panic!("no derivation finding in {f:#?}"));
    assert_eq!(derive.line, 25, "{derive}");
    let cache = f
        .iter()
        .find(|f| f.message.contains("blocks.0.k_cache") && f.message.contains("declares 512"))
        .unwrap_or_else(|| panic!("no cache-dim finding in {f:#?}"));
    assert!(
        (37..=44).contains(&cache.line),
        "finding should point into decode.state[1]'s shape block: {cache}"
    );
}

#[test]
fn param_total_mismatch_is_detected() {
    let (label, text) = golden_text("rom-tiny");
    let bad = text.replacen("\"total_params\": 853312", "\"total_params\": 853313", 1);
    assert_ne!(bad, text);
    let f = contract::check_manifest_bytes(&label, bad.as_bytes());
    let hit = f
        .iter()
        .find(|f| f.rule == "contract/analysis")
        .unwrap_or_else(|| panic!("no analysis finding in {f:#?}"));
    assert!(hit.message.contains("sum to 853312"), "{hit}");
    assert_eq!(hit.line, 6, "total_params sits on line 6: {hit}");
}

// ---------------------------------------------------------------------------
// Seeded corruption: schema drift (both directions)
// ---------------------------------------------------------------------------

fn real_doc_and_benches() -> (String, Vec<(String, String)>) {
    let root = repo_root();
    let doc = std::fs::read_to_string(root.join("EXPERIMENTS.md")).expect("EXPERIMENTS.md");
    let benches = ["bench_runtime", "bench_generate"]
        .iter()
        .map(|b| {
            let p = root.join("rust/benches").join(format!("{b}.rs"));
            (p.display().to_string(), std::fs::read_to_string(&p).expect("bench source"))
        })
        .collect();
    (doc, benches)
}

#[test]
fn removed_schema_row_fails_toward_the_emitter() {
    let (doc, benches) = real_doc_and_benches();
    let row_start = doc.find("| `fused_step_ms`").expect("row anchor");
    let row_end = row_start + doc[row_start..].find('\n').expect("row end") + 1;
    let mut doctored = doc.clone();
    doctored.replace_range(row_start..row_end, "");
    let f = schema::check_schema(&doctored, "EXPERIMENTS.md", &benches, None);
    let hit = f
        .iter()
        .find(|f| f.rule == schema::RULE_UNDOCUMENTED)
        .unwrap_or_else(|| panic!("no undocumented finding in {f:#?}"));
    assert!(hit.file.ends_with("bench_runtime.rs"), "{hit}");
    assert!(hit.message.contains("fused_step_ms"), "{hit}");
    assert!(hit.line > 1, "{hit}");
}

#[test]
fn bogus_schema_row_fails_toward_the_doc() {
    let (doc, benches) = real_doc_and_benches();
    let doctored = doc.replacen(
        "| `variant`",
        "| `imaginary_metric_ms` | ms | never emitted |\n| `variant`",
        1,
    );
    assert_ne!(doctored, doc);
    let f = schema::check_schema(&doctored, "EXPERIMENTS.md", &benches, None);
    let hit = f
        .iter()
        .find(|f| f.rule == schema::RULE_STALE)
        .unwrap_or_else(|| panic!("no stale finding in {f:#?}"));
    assert_eq!(hit.file, "EXPERIMENTS.md");
    assert!(hit.message.contains("imaginary_metric_ms"), "{hit}");
}

// ---------------------------------------------------------------------------
// Seeded corruption: lint
// ---------------------------------------------------------------------------

#[test]
fn smuggled_violations_are_detected_with_file_and_line() {
    let fixtures = vec![
        (
            "rust/src/coordinator/smuggled.rs".to_string(),
            "fn f() {\n    let x = g().unwrap();\n}\n".to_string(),
        ),
        (
            "rust/src/data/smuggled.rs".to_string(),
            "fn f() {\n    std::thread::spawn(|| {});\n}\n".to_string(),
        ),
        (
            "rust/src/runtime/smuggled.rs".to_string(),
            "fn f(p: *const u8) {\n    let _ = unsafe { *p };\n}\n".to_string(),
        ),
        (
            "rust/benches/smuggled.rs".to_string(),
            "fn f(d: &std::path::Path) {\n    std::fs::write(d.join(\"BENCH_runtime.json\"), b\"{}\").ok();\n}\n"
                .to_string(),
        ),
    ];
    let f = lint::lint_sources(&fixtures);
    for (rule, file) in [
        (lint::RULE_UNWRAP, "coordinator/smuggled.rs"),
        (lint::RULE_SPAWN, "data/smuggled.rs"),
        (lint::RULE_SAFETY, "runtime/smuggled.rs"),
        (lint::RULE_BENCH_WRITE, "benches/smuggled.rs"),
    ] {
        let hit = f
            .iter()
            .find(|f| f.rule == rule)
            .unwrap_or_else(|| panic!("no {rule} finding in {f:#?}"));
        assert!(hit.file.ends_with(file), "{hit}");
        assert_eq!(hit.line, 2, "each fixture plants its violation on line 2: {hit}");
    }
    assert_eq!(f.len(), 4, "exactly one finding per fixture: {f:#?}");
}
