//! Host-side property suites (no XLA): cross-module coordinator invariants
//! exercised with the proptest-lite framework. These complement the
//! per-module unit tests with randomized, seed-reproducible coverage.

use rom::coordinator::checkpoint::Checkpoint;
use rom::coordinator::metrics::Metrics;
use rom::coordinator::monitor::ExpertMonitor;
use rom::coordinator::schedule::CosineSchedule;
use rom::data::corpus::{Corpus, CorpusSpec};
use rom::data::loader::Loader;
use rom::data::probes::{make_cloze, make_continuation};
use rom::data::tokenizer::Tokenizer;
use rom::runtime::tensor::Tensor;
use rom::substrate::json::Json;
use rom::substrate::proptest::{check, Config};
use rom::substrate::rng::Rng;
use rom::{prop_assert, prop_assert_eq};

#[test]
fn prop_json_roundtrip_arbitrary_docs() {
    fn gen_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.below(2) == 1),
            2 => Json::Num((rng.next_f64() * 2000.0 - 1000.0).round()),
            3 => Json::Str(format!("s{}-\"quoted\"\n", rng.below(1000))),
            4 => Json::Arr((0..rng.below(5)).map(|_| gen_json(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), gen_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    check("json-rt", Config { cases: 100, seed: 21 }, |rng| {
        let doc = gen_json(rng, 3);
        let text = doc.to_string();
        let back = Json::parse(&text).map_err(|e| e.to_string())?;
        prop_assert_eq!(back, doc);
        Ok(())
    });
}

#[test]
fn prop_tensor_json_roundtrip() {
    check("tensor-json", Config { cases: 50, seed: 22 }, |rng| {
        let d0 = 1 + rng.below(6) as usize;
        let d1 = 1 + rng.below(6) as usize;
        let data: Vec<f32> = (0..d0 * d1)
            .map(|_| (rng.next_f64() as f32 - 0.5) * 100.0)
            .collect();
        let t = Tensor::f32(&[d0, d1], data);
        let back = Tensor::from_json(&t.to_json()).map_err(|e| e.to_string())?;
        prop_assert_eq!(back.shape, t.shape);
        prop_assert!(
            back.as_f32().unwrap().iter().zip(t.as_f32().unwrap()).all(
                |(a, b)| (a - b).abs() < 1e-4 * b.abs().max(1.0)
            ),
            "data drift through json"
        );
        Ok(())
    });
}

#[test]
fn prop_checkpoint_roundtrip_random_states() {
    let dir = std::env::temp_dir().join("rom_prop_ckpt");
    std::fs::create_dir_all(&dir).unwrap();
    check("ckpt-rt", Config { cases: 12, seed: 23 }, |rng| {
        let leaves = 1 + rng.below(6) as usize;
        let mk = |rng: &mut Rng| -> Vec<Tensor> {
            (0..leaves)
                .map(|_| {
                    let n = 1 + rng.below(64) as usize;
                    Tensor::f32(&[n], (0..n).map(|_| rng.next_f64() as f32).collect())
                })
                .collect()
        };
        let ck = Checkpoint { step: rng.below(10_000), params: mk(rng), m: mk(rng), v: mk(rng) };
        let path = dir.join(format!("p{}.ckpt", rng.below(u64::MAX)));
        ck.save(&path).map_err(|e| e.to_string())?;
        let back = Checkpoint::load(&path).map_err(|e| e.to_string())?;
        let _ = std::fs::remove_file(&path);
        prop_assert_eq!(back.step, ck.step);
        prop_assert_eq!(back.params.len(), leaves);
        for (a, b) in back.params.iter().zip(ck.params.iter()) {
            prop_assert_eq!(a.as_f32().unwrap(), b.as_f32().unwrap());
        }
        Ok(())
    });
}

#[test]
fn prop_monitor_load_conservation() {
    // Feeding valid per-router distributions keeps the EMA a distribution.
    check("monitor-conserve", Config { cases: 24, seed: 24 }, |rng| {
        let routers = 1 + rng.below(4) as usize;
        let experts = 2 + rng.below(7) as usize;
        let mut mon = ExpertMonitor::new(routers, experts);
        for _ in 0..30 {
            let mut load = vec![0f32; routers * experts];
            for r in 0..routers {
                let mut total = 0f32;
                for e in 0..experts {
                    let w = rng.next_f64() as f32;
                    load[r * experts + e] = w;
                    total += w;
                }
                for e in 0..experts {
                    load[r * experts + e] /= total;
                }
            }
            mon.observe(&load);
        }
        let rep = mon.report();
        prop_assert!(rep.max_over_uniform >= 1.0 - 1e-6, "max ratio < 1");
        prop_assert!(
            rep.norm_entropy > 0.0 && rep.norm_entropy <= 1.0 + 1e-9,
            "entropy {} out of range",
            rep.norm_entropy
        );
        Ok(())
    });
}

#[test]
fn prop_schedule_warmup_peak_equals_max() {
    check("sched-peak", Config { cases: 40, seed: 25 }, |rng| {
        let total = 20 + rng.below(5000);
        let max_lr = 1e-5 + rng.next_f64() * 1e-2;
        let s = CosineSchedule::new(max_lr, total, 0.01 + rng.next_f64() * 0.2);
        let peak = (1..=total).map(|t| s.lr(t)).fold(0.0, f64::max);
        prop_assert!(
            (peak - max_lr).abs() < 1e-12,
            "peak {peak} != max_lr {max_lr}"
        );
        Ok(())
    });
}

#[test]
fn prop_loader_covers_stream_once_per_epoch() {
    check("loader-cover", Config { cases: 16, seed: 26 }, |rng| {
        let t = 4 + rng.below(10) as usize;
        let windows = 3 + rng.below(8) as usize;
        let stream: Vec<i32> = (0..(t + 1) * windows).map(|i| i as i32).collect();
        let mut loader = Loader::new(stream, 1, t, rng.next_u64());
        let mut starts = std::collections::HashSet::new();
        for _ in 0..windows {
            let b = loader.next_batch();
            starts.insert(b.tokens.as_i32().unwrap()[0]);
        }
        // One epoch: every window visited exactly once.
        prop_assert_eq!(starts.len(), windows);
        Ok(())
    });
}

#[test]
fn prop_probe_instances_well_formed() {
    let corpus = Corpus::new(CorpusSpec::default(), 5);
    check("probe-form", Config { cases: 12, seed: 27 }, |rng| {
        let ctx = 8 + rng.below(48) as usize;
        for inst in make_cloze(&corpus, rng.next_u64(), 6, ctx) {
            prop_assert_eq!(inst.context.len(), ctx);
            prop_assert!(inst.answer < 4, "bad answer idx");
            prop_assert!(
                inst.options
                    .iter()
                    .all(|&o| (o as usize) < corpus.spec().vocab),
                "option out of vocab"
            );
        }
        let pre = 4 + rng.below(16) as usize;
        let cont = 2 + rng.below(8) as usize;
        for inst in make_continuation(&corpus, rng.next_u64(), 4, pre, cont) {
            prop_assert_eq!(inst.prefix.len(), pre);
            prop_assert!(inst.options.iter().all(|o| o.len() == cont), "ragged opts");
        }
        Ok(())
    });
}

#[test]
fn prop_tokenizer_never_loses_bytes() {
    let sample: Vec<u8> = (0u32..3000).map(|i| ((i * 17 + i / 9) % 251) as u8).collect();
    let tok = Tokenizer::train(&sample, 24);
    check("bpe-lossless", Config { cases: 40, seed: 28 }, |rng| {
        let len = rng.below(300) as usize;
        let text: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
        prop_assert_eq!(tok.decode(&tok.encode(&text)), text);
        Ok(())
    });
}

#[test]
fn prop_metrics_smoothing_bounded_by_extremes() {
    check("metrics-smooth", Config { cases: 30, seed: 29 }, |rng| {
        let mut m = Metrics::default();
        let n = 1 + rng.below(50);
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for i in 0..n {
            let loss = rng.next_f64() * 10.0;
            lo = lo.min(loss);
            hi = hi.max(loss);
            m.log_loss(i, loss, 1e-3, 0);
        }
        let s = m.smoothed_loss(10).unwrap();
        prop_assert!(s >= lo - 1e-12 && s <= hi + 1e-12, "{s} not in [{lo},{hi}]");
        Ok(())
    });
}

#[test]
fn prop_corpus_topic_clusters_align_with_ids() {
    let spec = CorpusSpec::default();
    let corpus = Corpus::new(spec.clone(), 11);
    check("corpus-topics", Config { cases: 20, seed: 30 }, |rng| {
        let toks = corpus.generate(rng.next_u64(), 500);
        for &t in &toks {
            match corpus.topic_of(t) {
                Some(topic) => {
                    prop_assert!(topic < spec.n_topics, "topic out of range");
                    prop_assert_eq!(topic, (t as usize) / spec.cluster);
                }
                None => prop_assert!(
                    (t as usize) >= spec.n_topics * spec.cluster,
                    "shared-band id misclassified"
                ),
            }
        }
        Ok(())
    });
}
