//! Serve integration: the continuous-batching engine's determinism contract
//! (every response bit-identical to a standalone `rom generate` run with the
//! same checkpoint/prompt/seed/params, regardless of admission order or slot
//! placement), backpressure on the bounded queue, clean drain/shutdown, and
//! the per-slot state-lane surgery it is built on.
//!
//! Requires `make artifacts` (tests skip politely when artifacts are absent
//! or predate the decoding subsystem).

use std::sync::Arc;

use rom::config::TrainCfg;
use rom::coordinator::checkpoint::Checkpoint;
use rom::coordinator::generate::{generate, GenerateCfg};
use rom::coordinator::serve::{Engine, FinishReason, Request, Response, ServeCfg, Submit};
use rom::coordinator::trainer::Trainer;
use rom::data::corpus::{Corpus, CorpusSpec};
use rom::runtime::artifact::Bundle;
use rom::runtime::session::Session;
use rom::runtime::tensor::Tensor;

fn artifacts_root() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Open a bundle iff it exists AND ships generation artifacts.
fn open_decodable(name: &str) -> Option<Arc<Bundle>> {
    if !artifacts_root().join(name).join("manifest.json").exists() {
        eprintln!("skipping: artifacts/{name} missing (run `make artifacts`)");
        return None;
    }
    let bundle = Bundle::open(artifacts_root().join(name)).unwrap();
    if bundle.manifest.decode.is_none() {
        eprintln!("skipping: artifacts/{name} predates decode artifacts");
        return None;
    }
    Some(bundle)
}

/// Train briefly and checkpoint, so logits are non-degenerate.
fn checkpoint_for_serving(bundle: &Arc<Bundle>) -> std::path::PathBuf {
    let cfg = TrainCfg { steps: 5, max_lr: 3e-3, log_every: 0, ..Default::default() };
    let mut trainer = Trainer::new(Arc::clone(bundle), cfg);
    trainer.quiet = true;
    trainer.final_eval = false;
    let (_report, sess) = trainer.run_session().unwrap();
    let (params, m, v) = sess.export().unwrap();
    let dir = std::env::temp_dir().join("rom_integration_serve");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(format!("{}.ckpt", bundle.manifest.name));
    Checkpoint { step: sess.step_count(), params, m, v }.save(&path).unwrap();
    path
}

/// The standalone `rom generate` run a serve response must reproduce.
fn reference_completion(sess: &Session, req: &Request) -> Vec<i32> {
    let cfg = GenerateCfg {
        max_new: req.max_new,
        temperature: req.temperature,
        top_k: req.top_k,
        seed: req.seed,
    };
    generate(sess, &[req.prompt.clone()], &cfg).unwrap().completions.remove(0)
}

#[test]
fn staggered_admissions_match_standalone_generate() {
    let Some(bundle) = open_decodable("mamba-tiny") else { return };
    let ckpt = checkpoint_for_serving(&bundle);
    let ck = Checkpoint::load(&ckpt).unwrap();
    let sess = Session::restore(Arc::clone(&bundle), &ck.params, &ck.m, &ck.v, ck.step).unwrap();
    let ctx = bundle.manifest.eval_lens[0]; // a prefill-artifact length

    // Mixed prompt LENGTHS in one request stream — the restriction `generate`
    // imposes (equal lengths per call) must not exist at the request level.
    // Request 0 rides the prefill artifact; 1 and 2 take the stepwise
    // fallback. Every request has its own seed and sampling params.
    let corpus = Corpus::new(CorpusSpec::default(), 17);
    let reqs = [
        Request {
            prompt: corpus.generate(901, ctx),
            max_new: 6,
            temperature: 0.9,
            top_k: 8,
            seed: 7,
            stop: None,
        },
        Request {
            prompt: corpus.generate(902, 9),
            max_new: 5,
            temperature: 0.0,
            top_k: 0,
            seed: 3,
            stop: None,
        },
        Request {
            prompt: corpus.generate(903, 9),
            max_new: 7,
            temperature: 1.1,
            top_k: 4,
            seed: 11,
            stop: None,
        },
    ];
    let refs: Vec<Vec<i32>> = reqs.iter().map(|r| reference_completion(&sess, r)).collect();

    // Staggered admission: request 0 decodes alone for a while before 1 and
    // 2 swap into whatever slots free up — placement must not matter.
    let mut engine = Engine::new(&sess, &ServeCfg { queue_cap: 8 }).unwrap();
    let mut responses: Vec<Response> = Vec::new();
    assert!(matches!(engine.submit(reqs[0].clone()).unwrap(), Submit::Accepted(0)));
    responses.extend(engine.step(&sess).unwrap());
    responses.extend(engine.step(&sess).unwrap());
    assert_eq!(engine.active(), 1, "request 0 should be mid-decode");
    assert!(matches!(engine.submit(reqs[1].clone()).unwrap(), Submit::Accepted(1)));
    assert!(matches!(engine.submit(reqs[2].clone()).unwrap(), Submit::Accepted(2)));
    responses.extend(engine.drain(&sess).unwrap());
    assert!(engine.idle());

    assert_eq!(responses.len(), 3);
    responses.sort_by_key(|r| r.id);
    for (i, (resp, reference)) in responses.iter().zip(&refs).enumerate() {
        assert_eq!(resp.id, i as u64);
        assert_eq!(resp.prompt, reqs[i].prompt);
        assert_eq!(
            &resp.tokens, reference,
            "request {i}: serve tokens diverged from standalone generate"
        );
        assert_eq!(resp.finish, FinishReason::MaxNew);
        // Latency accounting shape: wait precedes first token; one interval
        // per token after the first.
        assert!(resp.queue_wait_s <= resp.ttft_s);
        assert_eq!(resp.token_s.len(), resp.tokens.len() - 1);
    }
    assert_eq!(
        responses[0].prefill_artifact_tokens, ctx,
        "length {ctx} is consumed entirely by its artifact"
    );
    assert_eq!(
        responses[1].prefill_artifact_tokens, 0,
        "length 9 is shorter than every artifact: pure stepwise"
    );

    let rep = engine.report();
    assert_eq!(rep.completed, 3);
    assert_eq!(rep.emitted_tokens, 6 + 5 + 7);
    assert_eq!(rep.prefills, 3);
    assert!(rep.queue_wait.is_some() && rep.ttft.is_some() && rep.per_token.is_some());
    let _ = std::fs::remove_file(&ckpt);
}

#[test]
fn stop_token_finishes_early_with_reference_prefix() {
    let Some(bundle) = open_decodable("mamba-tiny") else { return };
    let sess = Session::init(Arc::clone(&bundle), 0).unwrap();
    let corpus = Corpus::new(CorpusSpec::default(), 17);
    let base = Request {
        prompt: corpus.generate(904, 9),
        max_new: 8,
        temperature: 0.9,
        top_k: 8,
        seed: 13,
        stop: None,
    };
    let reference = reference_completion(&sess, &base);

    // Stop on a token the reference run is known to emit: serve must return
    // exactly the reference prefix through its FIRST occurrence.
    let stop = reference[2];
    let cut = reference.iter().position(|&t| t == stop).unwrap();
    let mut engine = Engine::new(&sess, &ServeCfg::default()).unwrap();
    engine.submit(Request { stop: Some(stop), ..base }).unwrap();
    let responses = engine.drain(&sess).unwrap();
    assert_eq!(responses.len(), 1);
    assert_eq!(responses[0].tokens, reference[..=cut]);
    assert_eq!(responses[0].finish, FinishReason::Stop);
}

#[test]
fn backpressure_hands_back_requests_and_shutdown_is_clean() {
    let Some(bundle) = open_decodable("mamba-tiny") else { return };
    let sess = Session::init(Arc::clone(&bundle), 0).unwrap();
    let mut engine = Engine::new(&sess, &ServeCfg { queue_cap: 1 }).unwrap();
    let req = |seed: u64| Request {
        prompt: vec![1, 2, 3],
        max_new: 2,
        temperature: 0.0,
        top_k: 0,
        seed,
        stop: None,
    };

    // Invalid requests are errors (retrying cannot help) ...
    assert!(engine.submit(Request { prompt: vec![], ..req(0) }).is_err());
    assert!(engine.submit(Request { max_new: 0, ..req(0) }).is_err());
    let vocab = bundle.manifest.vocab_size as i32;
    assert!(engine.submit(Request { prompt: vec![vocab], ..req(0) }).is_err());

    // ... while a full queue is backpressure: the request comes back intact.
    assert!(matches!(engine.submit(req(0)).unwrap(), Submit::Accepted(_)));
    match engine.submit(req(1)).unwrap() {
        Submit::Rejected(r) => assert_eq!(r, req(1)),
        Submit::Accepted(id) => panic!("queue_cap 1 accepted a second request ({id})"),
    }
    assert_eq!(engine.queue_len(), 1);

    // Admission frees the queue; the bounced request goes through now.
    let mut responses = engine.step(&sess).unwrap();
    assert!(matches!(engine.submit(req(1)).unwrap(), Submit::Accepted(_)));

    // Clean shutdown: drain leaves the engine idle with everything answered.
    responses.extend(engine.drain(&sess).unwrap());
    assert!(engine.idle());
    assert_eq!(engine.active(), 0);
    assert_eq!(engine.queue_len(), 0);
    assert_eq!(responses.len(), 2);
    let rep = engine.report();
    assert_eq!(rep.completed, 2);
    assert_eq!(rep.emitted_tokens, 4);
    assert_eq!(engine.drain(&sess).unwrap().len(), 0, "idle drain is a no-op");
}

#[test]
fn full_attention_gangs_match_standalone_generate() {
    // Full-attention layouts serve through gang admission (every row shares
    // the `pos` scalar and the KV cache is position-indexed). Each response
    // must still be bit-identical to a standalone `rom generate` run, and a
    // SECOND gang of a different prompt length must start clean on a fresh
    // state — no leakage from the first gang's cache rows.
    let Some(bundle) = open_decodable("llama") else { return };
    let sess = Session::init(Arc::clone(&bundle), 0).unwrap();
    let batch = bundle.manifest.decode.as_ref().unwrap().batch;
    assert!(batch >= 2, "stock presets bake decode batch >= 2");

    let corpus = Corpus::new(CorpusSpec::default(), 17);
    let gang1 = [
        Request {
            prompt: corpus.generate(911, 9),
            max_new: 6,
            temperature: 0.9,
            top_k: 8,
            seed: 7,
            stop: None,
        },
        Request {
            prompt: corpus.generate(912, 9),
            max_new: 4,
            temperature: 0.0,
            top_k: 0,
            seed: 3,
            stop: None,
        },
    ];
    let gang2 = Request {
        prompt: corpus.generate(913, 13),
        max_new: 5,
        temperature: 1.1,
        top_k: 4,
        seed: 11,
        stop: None,
    };
    let refs: Vec<Vec<i32>> = gang1
        .iter()
        .chain([&gang2])
        .map(|r| reference_completion(&sess, r))
        .collect();

    let mut engine = Engine::new(&sess, &ServeCfg { queue_cap: 8 }).unwrap();
    for r in gang1.iter().chain([&gang2]) {
        assert!(matches!(engine.submit(r.clone()).unwrap(), Submit::Accepted(_)));
    }
    let mut responses = engine.drain(&sess).unwrap();
    assert!(engine.idle());
    assert_eq!(responses.len(), 3);
    responses.sort_by_key(|r| r.id);
    for (i, (resp, reference)) in responses.iter().zip(&refs).enumerate() {
        assert_eq!(
            &resp.tokens, reference,
            "request {i}: full-attention serve diverged from standalone generate"
        );
        assert_eq!(resp.finish, FinishReason::MaxNew);
    }
    // Gang scheduling: the 13-token request cannot join the 9-token gang, so
    // the engine ran (at least) two prefills — one per gang.
    assert!(engine.report().prefills >= 2);
}

#[test]
fn kv_cap_exhaustion_finishes_cleanly_mid_generation() {
    // A request whose prompt fits the KV cache but whose max_new would
    // outrun it is admitted and cut short: it keeps every token that fit
    // and finishes with KvCapExhausted — never a panic, and never a step
    // past the cap (which would silently clamp the cache scatter).
    let Some(bundle) = open_decodable("llama") else { return };
    let spec = bundle.manifest.decode.clone().unwrap();
    let cap = spec.kv_cap.expect("llama is a full-attention layout");
    let sess = Session::init(Arc::clone(&bundle), 0).unwrap();
    let corpus = Corpus::new(CorpusSpec::default(), 17);

    // A prompt longer than the cap can never be consumed: submit refuses.
    let impossible = Request {
        prompt: corpus.generate(920, cap + 1),
        max_new: 1,
        ..Request::default()
    };
    let err = sess_submit_err(&sess, impossible);
    assert!(err.contains("KV cache capacity"), "got: {err}");

    // prompt_len = cap - 3 leaves exactly 4 emittable tokens: the prompt
    // fills slots 0..cap-4, one token is sampled at admission, and three
    // decode steps write the last three cache slots before `pos` hits the
    // cap.
    let prompt = corpus.generate(921, cap - 3);
    let req = Request {
        prompt: prompt.clone(),
        max_new: 100,
        temperature: 0.9,
        top_k: 8,
        seed: 13,
        stop: None,
    };
    let mut engine = Engine::new(&sess, &ServeCfg::default()).unwrap();
    assert!(matches!(engine.submit(req).unwrap(), Submit::Accepted(_)));
    let responses = engine.drain(&sess).unwrap();
    assert!(engine.idle(), "exhaustion must not wedge the engine");
    assert_eq!(responses.len(), 1);
    let resp = &responses[0];
    assert_eq!(resp.finish, FinishReason::KvCapExhausted);
    assert_eq!(resp.tokens.len(), cap - prompt.len() + 1, "every slot that fit was used");

    // What DID fit is still bit-identical to a standalone generate run that
    // asked for exactly that many tokens.
    let cfg = GenerateCfg {
        max_new: resp.tokens.len(),
        temperature: 0.9,
        top_k: 8,
        seed: 13,
    };
    let reference = generate(&sess, &[prompt], &cfg).unwrap().completions.remove(0);
    assert_eq!(resp.tokens, reference, "the truncated stream diverged from generate");
}

/// Submit a request expected to fail validation; returns the error text.
fn sess_submit_err(sess: &Session, req: Request) -> String {
    let mut engine = Engine::new(sess, &ServeCfg::default()).unwrap();
    format!("{:#}", engine.submit(req).unwrap_err())
}

/// Bitwise equality of extracted state lanes.
fn lanes_eq(a: &[Tensor], b: &[Tensor]) -> bool {
    a.len() == b.len()
        && a.iter().zip(b).all(|(x, y)| {
            x.shape == y.shape
                && match (x.as_f32(), y.as_f32()) {
                    (Ok(xs), Ok(ys)) => {
                        xs.iter().zip(ys).all(|(p, q)| p.to_bits() == q.to_bits())
                    }
                    _ => x.as_i32().unwrap() == y.as_i32().unwrap(),
                }
        })
}

#[test]
fn state_row_extract_inject_roundtrip() {
    let Some(bundle) = open_decodable("mamba-tiny") else { return };
    let sess = Session::init(Arc::clone(&bundle), 0).unwrap();
    let b = bundle.manifest.decode.as_ref().unwrap().batch;
    assert!(b >= 2, "stock decode presets bake batch >= 2");

    // Advance two states on DIFFERENT token streams so their lanes diverge.
    let mut dst = sess.init_decode_state().unwrap();
    let mut src = sess.init_decode_state().unwrap();
    for t in 0..4 {
        sess.decode_step(&Tensor::i32(&[b], vec![1 + t; b]), &mut dst).unwrap();
        sess.decode_step(&Tensor::i32(&[b], vec![5 + t; b]), &mut src).unwrap();
    }
    let dst_row0 = sess.extract_state_row(&dst, 0).unwrap();
    let dst_row1 = sess.extract_state_row(&dst, 1).unwrap();
    let donor = sess.extract_state_row(&src, 0).unwrap();
    // Replicated token streams give identical rows within a state; the two
    // states differ from each other.
    assert!(lanes_eq(&dst_row0, &dst_row1));
    assert!(lanes_eq(&donor, &sess.extract_state_row(&src, 1).unwrap()));
    assert!(!lanes_eq(&dst_row1, &donor));

    // Inject src row 0 into dst row 1: row 1 becomes the donor bit-for-bit,
    // row 0 is untouched — the serve swap-in invariant.
    sess.inject_state_row(&mut dst, 1, &src, 0).unwrap();
    assert!(lanes_eq(&sess.extract_state_row(&dst, 1).unwrap(), &donor));
    assert!(lanes_eq(&sess.extract_state_row(&dst, 0).unwrap(), &dst_row0));

    // Out-of-range rows bail instead of corrupting state.
    assert!(sess.extract_state_row(&dst, b).is_err());
}
