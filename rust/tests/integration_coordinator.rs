//! Coordinator integration: full Trainer loop, checkpoint save/restore
//! equivalence, checkpoint retention, downstream probes above chance after
//! training, FLOPS mirror vs manifest, grad-accum trainer path, and the
//! experiment scheduler (serial/parallel determinism + failure isolation),
//! and data-parallel training (dp=2 bit-identical to dp=1 at the same
//! global batch; replica failure isolation). Requires `make artifacts`.

use std::sync::Arc;

use rom::config::{ModelCfg, TrainCfg};
use rom::coordinator::checkpoint::Checkpoint;
use rom::coordinator::downstream::score_cloze;
use rom::coordinator::eval::eval_ppl;
use rom::coordinator::trainer::Trainer;
use rom::data::corpus::{Corpus, CorpusSpec};
use rom::data::probes::make_cloze;
use rom::experiments::harness::RunSpec;
use rom::experiments::scheduler::run_sweep;
use rom::runtime::artifact::Bundle;
use rom::runtime::session::Session;

fn artifacts_root() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have(name: &str) -> bool {
    artifacts_root().join(name).join("manifest.json").exists()
}

fn open(name: &str) -> Arc<Bundle> {
    Bundle::open(artifacts_root().join(name)).unwrap()
}

#[test]
fn trainer_loop_reduces_loss_and_reports() {
    if !have("mamba-tiny") {
        eprintln!("skipping: artifacts missing");
        return;
    }
    let bundle = open("mamba-tiny");
    let cfg = TrainCfg { steps: 30, max_lr: 3e-3, log_every: 0, ..Default::default() };
    let mut trainer = Trainer::new(Arc::clone(&bundle), cfg);
    trainer.quiet = true;
    let report = trainer.run().unwrap();
    // 30 steps on structured data: loss must drop below the uniform floor
    // ln(512) = 6.24 at least slightly.
    assert!(report.final_loss.is_finite());
    assert!(
        report.smoothed_loss < 6.3,
        "loss {} did not move",
        report.smoothed_loss
    );
    assert!(report.tokens_per_sec > 0.0);
    assert_eq!(report.eval_ppl.len(), bundle.manifest.eval_lens.len());
    assert_eq!(report.metrics.losses.len(), 30);
    // Loss curve trend: mean of last 10 < mean of first 10.
    let first: f64 = report.metrics.losses[..10].iter().map(|p| p.loss).sum::<f64>() / 10.0;
    let last: f64 = report.metrics.losses[20..].iter().map(|p| p.loss).sum::<f64>() / 10.0;
    assert!(last < first, "no training progress: {first} -> {last}");
}

#[test]
fn checkpoint_restore_matches_session() {
    if !have("mamba-tiny") {
        eprintln!("skipping: artifacts missing");
        return;
    }
    let bundle = open("mamba-tiny");
    let man = bundle.manifest.clone();
    let mut sess = Session::init(Arc::clone(&bundle), 3).unwrap();
    // A couple of steps so state is non-trivial.
    let corpus = Corpus::new(CorpusSpec::default(), 17);
    let stream = corpus.generate(0, 4 * man.batch_size * (man.seq_len + 1));
    let mut loader = rom::data::loader::Loader::new(stream, man.batch_size, man.seq_len, 0);
    for _ in 0..2 {
        let b = loader.next_batch();
        sess.train_step(1e-3, &b.tokens, &b.targets).unwrap();
    }
    // Save -> restore -> identical eval NLL.
    let (params, m, v) = sess.export().unwrap();
    let dir = std::env::temp_dir().join("rom_integration_ckpt");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("restore.ckpt");
    Checkpoint { step: sess.step_count(), params, m, v }.save(&path).unwrap();

    let ck = Checkpoint::load(&path).unwrap();
    let sess2 =
        Session::restore(Arc::clone(&bundle), &ck.params, &ck.m, &ck.v, ck.step).unwrap();
    assert_eq!(sess2.step_count(), sess.step_count());
    let p1 = eval_ppl(&sess, &corpus, 5, 2, man.eval_lens[0]).unwrap();
    let p2 = eval_ppl(&sess2, &corpus, 5, 2, man.eval_lens[0]).unwrap();
    assert!((p1 - p2).abs() < 1e-6 * p1.max(1.0), "{p1} vs {p2}");
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn checkpoint_retention_prunes_old_checkpoints() {
    if !have("mamba-tiny") {
        eprintln!("skipping: artifacts missing");
        return;
    }
    let bundle = open("mamba-tiny");
    let dir = std::env::temp_dir().join("rom_integration_retention");
    let _ = std::fs::remove_dir_all(&dir);
    let cfg = TrainCfg {
        steps: 6,
        max_lr: 1e-3,
        checkpoint_every: 2,
        log_every: 0,
        ..Default::default()
    };
    let mut trainer = Trainer::new(Arc::clone(&bundle), cfg);
    trainer.quiet = true;
    trainer.final_eval = false;
    trainer.checkpoint_dir = Some(dir.clone());
    trainer.checkpoint_keep = Some(2);
    trainer.run().unwrap();
    // Saves land at steps 2/4/6 (+ the final save rewrites step 6); with
    // keep=2 only the two newest survive.
    let mut names: Vec<String> = std::fs::read_dir(&dir)
        .unwrap()
        .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
        .collect();
    names.sort();
    let prefix = format!("{}-step", bundle.manifest.name);
    assert_eq!(
        names,
        vec![format!("{prefix}4.ckpt"), format!("{prefix}6.ckpt")],
        "retention left the wrong checkpoint set"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn probes_score_and_flops_mirror() {
    if !have("rom-tiny") {
        eprintln!("skipping: artifacts missing");
        return;
    }
    let bundle = open("rom-tiny");
    // FLOPS mirror: rust formula == python-emitted manifest value.
    let cfg = ModelCfg::parse(&bundle.manifest.model).unwrap();
    let mirrored =
        rom::analysis::flops::flops_per_token(&cfg, bundle.manifest.seq_len).unwrap();
    let rel = (mirrored - bundle.manifest.analysis.fwd_flops_per_token).abs()
        / bundle.manifest.analysis.fwd_flops_per_token;
    assert!(rel < 1e-9, "flops mirror drifted: rel {rel}");

    // Probe scoring wiring: runs and returns sane values on an untrained
    // model (accuracy near chance, ppl finite).
    let sess = Session::init(Arc::clone(&bundle), 0).unwrap();
    let corpus = Corpus::new(CorpusSpec::default(), 17);
    let ctx = bundle.manifest.eval_lens[0];
    let result = score_cloze(&sess, &make_cloze(&corpus, 3, 8, ctx)).unwrap();
    assert_eq!(result.n, 8);
    assert!(result.accuracy >= 0.0 && result.accuracy <= 1.0);
    assert!(result.ppl().is_finite() && result.ppl() > 1.0);
}

#[test]
fn pipelined_trainer_matches_synchronous_exactly() {
    // Determinism guard for the two-stage prefetch pipeline: background
    // assembly + device encode must hand the step loop the exact same batch
    // stream as the synchronous in-loop path — bit-identical per-step losses,
    // for both the fused and the grad-accum path.
    if !have("mamba-tiny") {
        eprintln!("skipping: artifacts missing");
        return;
    }
    let bundle = open("mamba-tiny");
    for grad_accum in [false, true] {
        if grad_accum && bundle.manifest.batch_size % bundle.manifest.micro_batch != 0 {
            continue;
        }
        let cfg = TrainCfg {
            steps: 8,
            max_lr: 3e-3,
            grad_accum,
            log_every: 3, // off-cadence sampling must not perturb the loop
            eval_every: 0,
            ..Default::default()
        };
        let run = |pipelined: bool| {
            let mut trainer = Trainer::new(Arc::clone(&bundle), cfg.clone());
            trainer.quiet = true;
            trainer.pipelined = pipelined;
            trainer.run().unwrap()
        };
        let piped = run(true);
        let sync = run(false);
        assert_eq!(piped.metrics.losses.len(), sync.metrics.losses.len());
        for (a, b) in piped.metrics.losses.iter().zip(sync.metrics.losses.iter()) {
            assert_eq!(
                a.loss.to_bits(),
                b.loss.to_bits(),
                "grad_accum={grad_accum} step {}: pipelined {} != synchronous {}",
                a.step,
                a.loss,
                b.loss
            );
        }
    }
}

#[test]
fn trainer_grad_accum_path_runs() {
    if !have("mamba-tiny") {
        eprintln!("skipping: artifacts missing");
        return;
    }
    let bundle = open("mamba-tiny");
    if bundle.manifest.batch_size % bundle.manifest.micro_batch != 0 {
        return;
    }
    let cfg = TrainCfg {
        steps: 4,
        max_lr: 1e-3,
        grad_accum: true,
        log_every: 0,
        ..Default::default()
    };
    let mut trainer = Trainer::new(Arc::clone(&bundle), cfg);
    trainer.quiet = true;
    let report = trainer.run().unwrap();
    assert!(report.final_loss.is_finite());
    assert_eq!(report.metrics.losses.len(), 4);
}

#[test]
fn dp_two_replicas_bit_identical_to_dp_one() {
    // The `--dp` acceptance guard: two replicas at the same GLOBAL batch
    // must reproduce the one-replica run bit for bit — per-step losses AND
    // the bytes of the final checkpoint. The host-side reduction sums raw
    // per-microbatch gradients in global rank-major order, so the float
    // association is identical no matter how many replicas contributed.
    if !have("mamba-tiny") {
        eprintln!("skipping: artifacts missing");
        return;
    }
    let bundle = open("mamba-tiny");
    if bundle.manifest.batch_size % 2 != 0 {
        eprintln!("skipping: batch size not divisible by 2");
        return;
    }
    let run = |world: usize| {
        let dir = std::env::temp_dir().join(format!("rom_integration_dp{world}"));
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = TrainCfg { steps: 6, max_lr: 3e-3, log_every: 0, ..Default::default() };
        let mut trainer = Trainer::new(Arc::clone(&bundle), cfg);
        trainer.quiet = true;
        trainer.final_eval = false;
        trainer.dp = Some(world);
        trainer.checkpoint_dir = Some(dir.clone());
        let report = trainer.run().unwrap();
        let ckpt = std::fs::read(
            dir.join(format!("{}-step6.ckpt", bundle.manifest.name)),
        )
        .unwrap();
        std::fs::remove_dir_all(&dir).unwrap();
        (report, ckpt)
    };
    let (r1, ck1) = run(1);
    let (r2, ck2) = run(2);
    assert_eq!(r2.dp_stats.expect("dp run must report dp stats").world, 2);
    assert_eq!(r1.metrics.losses.len(), r2.metrics.losses.len());
    for (a, b) in r1.metrics.losses.iter().zip(r2.metrics.losses.iter()) {
        assert_eq!(
            a.loss.to_bits(),
            b.loss.to_bits(),
            "step {}: dp=1 loss {} != dp=2 loss {}",
            a.step,
            a.loss,
            b.loss
        );
    }
    assert_eq!(ck1, ck2, "final checkpoint bytes differ between dp=1 and dp=2");
}

#[test]
fn dp_replica_failure_names_rank_and_drains() {
    // Per-rank failure isolation: a replica that panics mid-run must surface
    // as an error naming its rank, while the surviving replicas unblock from
    // the gradient barrier and drain instead of deadlocking the run.
    if !have("mamba-tiny") {
        eprintln!("skipping: artifacts missing");
        return;
    }
    let bundle = open("mamba-tiny");
    if bundle.manifest.batch_size % 2 != 0 {
        eprintln!("skipping: batch size not divisible by 2");
        return;
    }
    let cfg = TrainCfg { steps: 4, max_lr: 1e-3, log_every: 0, ..Default::default() };
    let mut trainer = Trainer::new(Arc::clone(&bundle), cfg);
    trainer.quiet = true;
    trainer.final_eval = false;
    trainer.dp = Some(2);
    trainer.dp_fault = Some((1, 2));
    let err = trainer.run().expect_err("injected replica fault must fail the run");
    let msg = format!("{err:#}");
    assert!(msg.contains("replica 1"), "error must name the failing rank: {msg}");
    assert!(
        msg.contains("drained cleanly"),
        "error must report the surviving replicas drained: {msg}"
    );
    assert!(
        msg.contains("fault injection"),
        "root cause (the panic message) must survive into the error: {msg}"
    );
}

#[test]
fn scheduler_parallel_sweep_matches_serial() {
    // The acceptance guard for `--jobs N`: a 2-variant sweep run serially
    // and on 2 workers must produce bit-identical per-variant final losses
    // AND byte-identical table rows (`run_rows` is the exact path behind
    // `rom experiment <id>`).
    if !(have("mamba-tiny") && have("rom-tiny")) {
        eprintln!("skipping: artifacts missing");
        return;
    }
    let variants: Vec<String> = vec!["mamba-tiny".into(), "rom-tiny".into()];
    let mut spec = RunSpec::new(6, 3e-3);
    spec.quiet = true;
    let serial = run_sweep(&variants, &spec, 1);
    let parallel = run_sweep(&variants, &spec, 2);
    assert_eq!(serial.len(), 2);
    for ((name, a), b) in variants.iter().zip(&serial).zip(&parallel) {
        let (a, b) = (a.as_ref().unwrap(), b.as_ref().unwrap());
        assert_eq!(a.name, *name, "row order must follow variant order");
        assert_eq!(
            a.final_loss.to_bits(),
            b.final_loss.to_bits(),
            "{name}: serial loss {} != parallel loss {}",
            a.final_loss,
            b.final_loss
        );
        assert_eq!(a.smoothed_loss.to_bits(), b.smoothed_loss.to_bits());
        assert_eq!(a.ppl.len(), b.ppl.len());
        for ((ca, pa), (cb, pb)) in a.ppl.iter().zip(b.ppl.iter()) {
            assert_eq!(ca, cb);
            assert_eq!(pa.to_bits(), pb.to_bits(), "{name}: ppl@{ca} differs");
        }
    }

    // Full table-row comparison through the real row formatter.
    let rows = |jobs: usize| {
        rom::experiments::tables::run_rows(
            "scheduler determinism guard",
            &["mamba-tiny", "rom-tiny"],
            6,
            jobs,
        )
        .unwrap()
        .rows()
        .to_vec()
    };
    let rows_serial = rows(1);
    let rows_parallel = rows(2);
    assert_eq!(rows_serial.len(), 2);
    assert_eq!(rows_serial, rows_parallel, "table rows differ across --jobs");
}

#[test]
fn scheduler_isolates_failing_variant() {
    // One variant without artifacts fails its own row; the sibling rows
    // (including one scheduled AFTER the failure) complete and match the
    // all-good run bit for bit.
    if !have("mamba-tiny") {
        eprintln!("skipping: artifacts missing");
        return;
    }
    let variants: Vec<String> = vec![
        "mamba-tiny".into(),
        "no-such-variant-xyz".into(),
        "mamba-tiny".into(),
    ];
    let mut spec = RunSpec::new(4, 3e-3);
    spec.quiet = true;
    spec.final_eval = false;
    let results = run_sweep(&variants, &spec, 2);
    assert_eq!(results.len(), 3);
    let first = results[0].as_ref().expect("healthy variant failed");
    assert!(results[1].is_err(), "missing artifacts must surface as Err");
    let third = results[2].as_ref().expect("variant after the failure was poisoned");
    // Same variant, same spec, isolated workers: identical training.
    assert_eq!(first.final_loss.to_bits(), third.final_loss.to_bits());
}
