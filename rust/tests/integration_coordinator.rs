//! Coordinator integration: full Trainer loop, checkpoint save/restore
//! equivalence, downstream probes above chance after training, FLOPS mirror
//! vs manifest, and grad-accum trainer path. Requires `make artifacts`.

use rom::config::{ModelCfg, TrainCfg};
use rom::coordinator::checkpoint::Checkpoint;
use rom::coordinator::downstream::score_cloze;
use rom::coordinator::eval::eval_ppl;
use rom::coordinator::trainer::Trainer;
use rom::data::corpus::{Corpus, CorpusSpec};
use rom::data::probes::make_cloze;
use rom::runtime::artifact::{cpu_client, Bundle};
use rom::runtime::session::Session;

fn artifacts_root() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have(name: &str) -> bool {
    artifacts_root().join(name).join("manifest.json").exists()
}

#[test]
fn trainer_loop_reduces_loss_and_reports() {
    if !have("mamba-tiny") {
        eprintln!("skipping: artifacts missing");
        return;
    }
    let client = cpu_client().unwrap();
    let bundle = Bundle::load(client, artifacts_root().join("mamba-tiny")).unwrap();
    let cfg = TrainCfg { steps: 30, max_lr: 3e-3, log_every: 0, ..Default::default() };
    let mut trainer = Trainer::new(&bundle, cfg);
    trainer.quiet = true;
    let report = trainer.run().unwrap();
    // 30 steps on structured data: loss must drop below the uniform floor
    // ln(512) = 6.24 at least slightly.
    assert!(report.final_loss.is_finite());
    assert!(
        report.smoothed_loss < 6.3,
        "loss {} did not move",
        report.smoothed_loss
    );
    assert!(report.tokens_per_sec > 0.0);
    assert_eq!(report.eval_ppl.len(), bundle.manifest.eval_lens.len());
    assert_eq!(report.metrics.losses.len(), 30);
    // Loss curve trend: mean of last 10 < mean of first 10.
    let first: f64 = report.metrics.losses[..10].iter().map(|p| p.loss).sum::<f64>() / 10.0;
    let last: f64 = report.metrics.losses[20..].iter().map(|p| p.loss).sum::<f64>() / 10.0;
    assert!(last < first, "no training progress: {first} -> {last}");
}

#[test]
fn checkpoint_restore_matches_session() {
    if !have("mamba-tiny") {
        eprintln!("skipping: artifacts missing");
        return;
    }
    let client = cpu_client().unwrap();
    let bundle = Bundle::load(client, artifacts_root().join("mamba-tiny")).unwrap();
    let man = bundle.manifest.clone();
    let mut sess = Session::init(&bundle, 3).unwrap();
    // A couple of steps so state is non-trivial.
    let corpus = Corpus::new(CorpusSpec::default(), 17);
    let stream = corpus.generate(0, 4 * man.batch_size * (man.seq_len + 1));
    let mut loader = rom::data::loader::Loader::new(stream, man.batch_size, man.seq_len, 0);
    for _ in 0..2 {
        let b = loader.next_batch();
        sess.train_step(1e-3, &b.tokens, &b.targets).unwrap();
    }
    // Save -> restore -> identical eval NLL.
    let (params, m, v) = sess.export().unwrap();
    let dir = std::env::temp_dir().join("rom_integration_ckpt");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("restore.ckpt");
    Checkpoint { step: sess.step_count(), params, m, v }.save(&path).unwrap();

    let ck = Checkpoint::load(&path).unwrap();
    let sess2 = Session::restore(&bundle, &ck.params, &ck.m, &ck.v, ck.step).unwrap();
    assert_eq!(sess2.step_count(), sess.step_count());
    let p1 = eval_ppl(&sess, &corpus, 5, 2, man.eval_lens[0]).unwrap();
    let p2 = eval_ppl(&sess2, &corpus, 5, 2, man.eval_lens[0]).unwrap();
    assert!((p1 - p2).abs() < 1e-6 * p1.max(1.0), "{p1} vs {p2}");
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn probes_score_and_flops_mirror() {
    if !have("rom-tiny") {
        eprintln!("skipping: artifacts missing");
        return;
    }
    let client = cpu_client().unwrap();
    let bundle = Bundle::load(client, artifacts_root().join("rom-tiny")).unwrap();
    // FLOPS mirror: rust formula == python-emitted manifest value.
    let cfg = ModelCfg::parse(&bundle.manifest.model).unwrap();
    let mirrored =
        rom::analysis::flops::flops_per_token(&cfg, bundle.manifest.seq_len).unwrap();
    let rel = (mirrored - bundle.manifest.analysis.fwd_flops_per_token).abs()
        / bundle.manifest.analysis.fwd_flops_per_token;
    assert!(rel < 1e-9, "flops mirror drifted: rel {rel}");

    // Probe scoring wiring: runs and returns sane values on an untrained
    // model (accuracy near chance, ppl finite).
    let sess = Session::init(&bundle, 0).unwrap();
    let corpus = Corpus::new(CorpusSpec::default(), 17);
    let ctx = bundle.manifest.eval_lens[0];
    let result = score_cloze(&sess, &make_cloze(&corpus, 3, 8, ctx)).unwrap();
    assert_eq!(result.n, 8);
    assert!(result.accuracy >= 0.0 && result.accuracy <= 1.0);
    assert!(result.ppl().is_finite() && result.ppl() > 1.0);
}

#[test]
fn pipelined_trainer_matches_synchronous_exactly() {
    // Determinism guard for the two-stage prefetch pipeline: background
    // assembly + device encode must hand the step loop the exact same batch
    // stream as the synchronous in-loop path — bit-identical per-step losses,
    // for both the fused and the grad-accum path.
    if !have("mamba-tiny") {
        eprintln!("skipping: artifacts missing");
        return;
    }
    let client = cpu_client().unwrap();
    let bundle = Bundle::load(client, artifacts_root().join("mamba-tiny")).unwrap();
    for grad_accum in [false, true] {
        if grad_accum && bundle.manifest.batch_size % bundle.manifest.micro_batch != 0 {
            continue;
        }
        let cfg = TrainCfg {
            steps: 8,
            max_lr: 3e-3,
            grad_accum,
            log_every: 3, // off-cadence sampling must not perturb the loop
            eval_every: 0,
            ..Default::default()
        };
        let run = |pipelined: bool| {
            let mut trainer = Trainer::new(&bundle, cfg.clone());
            trainer.quiet = true;
            trainer.pipelined = pipelined;
            trainer.run().unwrap()
        };
        let piped = run(true);
        let sync = run(false);
        assert_eq!(piped.metrics.losses.len(), sync.metrics.losses.len());
        for (a, b) in piped.metrics.losses.iter().zip(sync.metrics.losses.iter()) {
            assert_eq!(
                a.loss.to_bits(),
                b.loss.to_bits(),
                "grad_accum={grad_accum} step {}: pipelined {} != synchronous {}",
                a.step,
                a.loss,
                b.loss
            );
        }
    }
}

#[test]
fn trainer_grad_accum_path_runs() {
    if !have("mamba-tiny") {
        eprintln!("skipping: artifacts missing");
        return;
    }
    let client = cpu_client().unwrap();
    let bundle = Bundle::load(client, artifacts_root().join("mamba-tiny")).unwrap();
    if bundle.manifest.batch_size % bundle.manifest.micro_batch != 0 {
        return;
    }
    let cfg = TrainCfg {
        steps: 4,
        max_lr: 1e-3,
        grad_accum: true,
        log_every: 0,
        ..Default::default()
    };
    let mut trainer = Trainer::new(&bundle, cfg);
    trainer.quiet = true;
    let report = trainer.run().unwrap();
    assert!(report.final_loss.is_finite());
    assert_eq!(report.metrics.losses.len(), 4);
}
