//! Loom model-checking of `substrate::pool` + the `substrate::sync` channel
//! shim.
//!
//! Build with `RUSTFLAGS="--cfg loom" cargo test --release --test loom_pool`
//! (requires the `loom` dev-dependency). Under `--cfg loom`,
//! `substrate::sync` swaps std's `Mutex`/`Condvar`/`thread` and the mpsc
//! re-export for loom's model-checked versions plus a hand-rolled bounded
//! channel built on them, so every interleaving of the models below is
//! explored exhaustively — including the shutdown races the unit tests can
//! only sample: a producer blocked in `send` while the consumer drops,
//! `Drop` joining threads that are mid-handoff, and a `reduce_group`
//! member departing while a peer is parked in the gradient-exchange
//! barrier.
//!
//! Models are deliberately tiny (loom caps at 4 threads and state space is
//! exponential): 1-worker pools, depth-1 channels, 1–2 items.
#![allow(unknown_lints)]
#![allow(unexpected_cfgs)]
#![cfg(loom)]

use loom::sync::atomic::{AtomicUsize, Ordering};
use loom::sync::Arc;

use rom::substrate::pool::{
    line_pump, reduce_group, Pipeline, Prefetcher, ReduceError, ThreadPool,
};
use rom::substrate::sync::mpsc::sync_channel;

#[test]
fn pool_submit_join_sees_all_jobs() {
    loom::model(|| {
        let pool = ThreadPool::new(1);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..2 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 2);
        drop(pool);
    });
}

#[test]
fn pool_drop_without_join_drains_queued_jobs() {
    loom::model(|| {
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let pool = ThreadPool::new(1);
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
            // Drop immediately: the worker must still drain the queued job
            // before exiting on channel disconnect (Drop joins it).
        }
        assert_eq!(counter.load(Ordering::SeqCst), 1);
    });
}

#[test]
fn prefetcher_drains_then_terminates() {
    loom::model(|| {
        let mut n = 0u32;
        let pf = Prefetcher::new(1, move || {
            n += 1;
            if n <= 2 {
                Some(n)
            } else {
                None
            }
        });
        assert_eq!(pf.next(), Some(1));
        assert_eq!(pf.next(), Some(2));
        assert_eq!(pf.next(), None);
        drop(pf); // Drop joins an already-exited worker: must not hang
    });
}

#[test]
fn prefetcher_drop_unblocks_a_sending_producer() {
    loom::model(|| {
        // Infinite producer, depth 1: after one item is consumed the
        // producer is parked in `send` on a full channel. Drop must
        // disconnect the receiver, wake it with SendError, and join.
        let pf = Prefetcher::new(1, || Some(()));
        assert_eq!(pf.next(), Some(()));
        drop(pf);
    });
}

#[test]
fn pipeline_preserves_order_and_terminates() {
    loom::model(|| {
        let mut n = 0u32;
        let pl = Pipeline::new(
            1,
            move || {
                n += 1;
                if n <= 2 {
                    Some(n)
                } else {
                    None
                }
            },
            |x| x * 10,
        );
        assert_eq!(pl.next(), Some(10));
        assert_eq!(pl.next(), Some(20));
        assert_eq!(pl.next(), None);
        drop(pl);
    });
}

#[test]
fn pipeline_drop_mid_stream_unwinds_both_stages() {
    loom::model(|| {
        // Infinite stage 1, depth-1 channels: dropping the consumer while
        // items are in flight must cascade — stage 2 wakes on send Err,
        // its exit disconnects rx1, stage 1 wakes in turn, Drop joins both.
        let pl = Pipeline::new(1, || Some(1u32), |x| x);
        assert_eq!(pl.next(), Some(1));
        drop(pl);
    });
}

#[test]
fn reduce_group_folds_in_rank_order() {
    loom::model(|| {
        // Two members, arrival order decided by the scheduler; the fold must
        // always see contributions slot-ordered by rank, never by arrival.
        let mut members = reduce_group(2, |v: Vec<u32>| v);
        let m1 = members.pop().unwrap();
        let m0 = members.pop().unwrap();
        let h = loom::thread::spawn(move || {
            let r = m1.reduce(20).unwrap();
            assert_eq!(*r, vec![10, 20]);
        });
        let r = m0.reduce(10).unwrap();
        assert_eq!(*r, vec![10, 20]);
        h.join().unwrap();
    });
}

#[test]
fn reduce_member_drop_mid_barrier_unblocks_peer() {
    loom::model(|| {
        // The dp failure mode: a replica unwinds (dropping its member)
        // while a peer is parked in the barrier. Whether the drop lands
        // before or after the peer arrives, the peer must get ReduceError —
        // never deadlock, never a partial fold.
        let mut members = reduce_group(2, |v: Vec<u32>| v);
        let m1 = members.pop().unwrap();
        let m0 = members.pop().unwrap();
        let h = loom::thread::spawn(move || drop(m1));
        assert_eq!(m0.reduce(10).unwrap_err(), ReduceError);
        h.join().unwrap();
    });
}

#[test]
fn reduce_member_drop_after_round_fails_next_round() {
    loom::model(|| {
        // A reducer unwinding mid-stream: round 0 completes on both ranks,
        // then rank 1 departs. Rank 0's next round must error out whether it
        // arrives before or after the departure is recorded.
        let mut members = reduce_group(2, |v: Vec<u32>| v.iter().sum::<u32>());
        let m1 = members.pop().unwrap();
        let m0 = members.pop().unwrap();
        let h = loom::thread::spawn(move || {
            assert_eq!(*m1.reduce(2).unwrap(), 3);
            // m1 drops here — mid-stream from rank 0's point of view.
        });
        assert_eq!(*m0.reduce(1).unwrap(), 3);
        assert_eq!(m0.reduce(1).unwrap_err(), ReduceError);
        h.join().unwrap();
    });
}

#[test]
fn channel_fifo_and_disconnect_on_sender_drop() {
    loom::model(|| {
        let (tx, rx) = sync_channel::<u32>(1);
        let sender = loom::thread::spawn(move || {
            tx.send(1).unwrap();
            tx.send(2).unwrap();
            // tx drops here: receiver must see disconnect after draining.
        });
        assert_eq!(rx.recv().unwrap(), 1);
        assert_eq!(rx.recv().unwrap(), 2);
        assert!(rx.recv().is_err());
        sender.join().unwrap();
    });
}

#[test]
fn channel_send_errors_once_receiver_gone() {
    loom::model(|| {
        let (tx, rx) = sync_channel::<u32>(1);
        let sender = loom::thread::spawn(move || {
            let mut sent = 0usize;
            // Send until the receiver disappears; must terminate (never
            // deadlock on a full channel with no receiver) and hand the
            // rejected value back.
            loop {
                match tx.send(7) {
                    Ok(()) => sent += 1,
                    Err(e) => {
                        assert_eq!(e.0, 7);
                        break;
                    }
                }
                if sent > 3 {
                    panic!("receiver gone but sends kept succeeding");
                }
            }
        });
        let _ = rx.recv();
        drop(rx);
        sender.join().unwrap();
    });
}

#[test]
fn line_pump_consumer_drop_stops_the_pump() {
    loom::model(|| {
        let (rx, h) = line_pump(Box::new(std::io::Cursor::new(b"a\nb\nc\n".to_vec())), 1);
        assert_eq!(rx.recv().unwrap(), "a");
        drop(rx);
        h.join().unwrap().unwrap();
    });
}
