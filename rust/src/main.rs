//! `rom` — the RoM training coordinator CLI (the launcher of DESIGN.md §2).
//!
//! Subcommands (see the USAGE string for flags):
//!
//! ```text
//! list                   variants with artifacts present
//! info <variant>         manifest + analytic accounting
//! train <variant>        train from scratch on the synthetic corpus
//! eval <variant>         PPL sweep from a checkpoint
//! generate <variant>     autoregressive decoding from a checkpoint
//! serve <variant>        continuous-batching generation service
//! probes <variant>       downstream probe scores (Table 2 stand-in)
//! experiment <id>        regenerate a paper table/figure
//! analyze                offline static checks: manifest contract, bench
//!                        schema drift, source lint
//! ```

use std::collections::VecDeque;
use std::io::BufRead;
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};
use rom::config::TrainCfg;
use rom::coordinator::checkpoint::Checkpoint;
use rom::coordinator::downstream::{score_cloze, score_continuation};
use rom::coordinator::eval::eval_ppl_sweep;
use rom::coordinator::generate::{generate, parse_prompt_tokens, GenerateCfg};
use rom::coordinator::serve::{
    parse_request_line, Engine, FinishReason, Request as ServeRequest, ServeCfg,
    Submit,
};
use rom::coordinator::trainer::Trainer;
use rom::data::corpus::{Corpus, CorpusSpec};
use rom::data::probes::{make_cloze, make_continuation};
use rom::experiments::harness::{artifacts_root, dp_budget, lr_budget};
use rom::experiments::scheduler::default_jobs;
use rom::experiments::tables::run_experiment;
use rom::info;
use rom::runtime::artifact::Bundle;
use rom::runtime::session::Session;
use rom::substrate::cli::Args;
use rom::substrate::pool::line_pump;
use rom::substrate::sync::mpsc::TryRecvError;

const USAGE: &str = "\
rom — Routing Mamba training coordinator
usage: rom <subcommand> [options]
  list                              show variants with artifacts
  info <variant>                    manifest + analytic accounting
  train <variant> [--steps N] [--lr X] [--warmup R] [--seed N] [--accum]
                  [--dp K] [--ckpt-dir D] [--ckpt-every N] [--ckpt-keep N]
                  [--eval-every N] [--log-every N] [--metrics FILE]
                  (--ckpt-keep N retains only the newest N checkpoints;
                   --dp K, or ROM_DP, trains K data-parallel replicas with
                   deterministic host-side gradient reduction — same global
                   batch, bit-identical losses to --dp 1)
  eval <variant> --ckpt FILE        PPL sweep from a checkpoint
  generate <variant> --ckpt FILE --prompt-tokens '1,2,3[;4,5,6]'
                  [--max-new N] [--temperature X] [--top-k K] [--seed N]
                                    autoregressive decoding: batched prompts
                                    (';'-separated — quote the value — equal
                                    lengths), greedy by default,
                                    temperature/top-k sampling on a seeded
                                    stream; prints per-token latency
  serve <variant> --ckpt FILE       continuous-batching generation service:
                  [--requests FILE] [--max-new N] [--temperature X]
                  [--top-k K] [--seed N] [--stop TOK] [--queue N]
                                    reads request lines from --requests (or
                                    stdin): 'TOKENS [max-new=N] [seed=N]
                                    [temperature=X] [top-k=K] [stop=T]';
                                    prompts of different lengths share the
                                    decode batch (slot swap-in); each
                                    response is bit-identical to a
                                    standalone `rom generate` run with the
                                    same params
  probes <variant> [--steps N] [--lr X]
                                    downstream probes (Table 2 stand-in)
  experiment <id> [--steps N] [--jobs N]
                                    regenerate a table/figure
                                    (fig2 fig3 fig4 table1 table2 table3
                                     table6 table10 table11)
                                    --jobs N trains N variants in parallel
                                    (default from ROM_JOBS, else 1; rows are
                                    byte-identical to a serial run); ROM_DP=K
                                    trains each variant data-parallel and
                                    divides the default --jobs by K
  analyze [--manifest FILE] [--golden]
                                    offline static checks, no device needed:
                                    manifest contract (golden fixtures +
                                    artifacts/ when present), BENCH schema
                                    vs EXPERIMENTS.md drift, source lint.
                                    --golden checks only the committed
                                    fixtures; --manifest FILE checks one
                                    manifest. Findings print as
                                    file:line: [rule] message; exits
                                    non-zero if any
";

fn main() -> Result<()> {
    let args = Args::from_env(&["accum", "quiet", "help", "golden"]);
    if args.has_flag("help") {
        print!("{USAGE}");
        return Ok(());
    }
    match args.subcommand.as_deref() {
        Some("list") => list(),
        Some("info") => info_cmd(&args),
        Some("train") => train(&args),
        Some("eval") => eval_cmd(&args),
        Some("generate") => generate_cmd(&args),
        Some("serve") => serve_cmd(&args),
        Some("probes") => probes(&args),
        Some("experiment") => experiment(&args),
        Some("analyze") => analyze_cmd(&args),
        Some("help") | None => {
            print!("{USAGE}");
            Ok(())
        }
        Some(other) => Err(usage_err(format!("unknown subcommand {other:?}"))),
    }
}

/// A bad-invocation error: the message followed by the full USAGE text, so
/// every `rom <subcommand>` misuse points at the same reference.
fn usage_err(msg: impl std::fmt::Display) -> anyhow::Error {
    anyhow!("{msg}\n\n{USAGE}")
}

fn variant_arg(args: &Args) -> Result<String> {
    args.positional
        .first()
        .cloned()
        .ok_or_else(|| usage_err("missing <variant> argument"))
}

/// A required `--key value` option, with a USAGE-pointing error when absent.
fn required_opt<'a>(args: &'a Args, key: &str) -> Result<&'a str> {
    args.get(key).ok_or_else(|| usage_err(format!("--{key} is required")))
}

/// `rom analyze` — the offline static-analysis gate. Default run covers the
/// committed golden manifests, any freshly emitted `artifacts/*/manifest.json`,
/// the BENCH schema/doc diff, and the source lint; `--golden` narrows to the
/// fixtures, `--manifest FILE` to a single file.
fn analyze_cmd(args: &Args) -> Result<()> {
    use rom::analysis::{contract, lint, repo_root, schema, Finding};

    let mut findings: Vec<Finding> = Vec::new();
    let mut checked = 0usize;

    if let Some(path) = args.get("manifest") {
        findings.extend(contract::check_manifest_file(std::path::Path::new(path)));
        checked += 1;
    } else {
        let root = repo_root();
        let goldens = contract::golden_manifests(&root);
        if goldens.is_empty() {
            bail!(
                "no golden manifests under {} — the contract pass has nothing \
                 to check",
                root.join("rust/tests/golden").display()
            );
        }
        for p in &goldens {
            findings.extend(contract::check_manifest_file(p));
            checked += 1;
        }
        if !args.has_flag("golden") {
            for p in contract::artifact_manifests(&artifacts_root()) {
                findings.extend(contract::check_manifest_file(&p));
                checked += 1;
            }
            findings.extend(schema::check_tree(&root));
            findings.extend(lint::lint_tree(&root));
        }
    }

    findings.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    for f in &findings {
        eprintln!("{f}");
    }
    if !findings.is_empty() {
        bail!("analyze: {} finding(s)", findings.len());
    }
    let scope = if args.get("manifest").is_some() || args.has_flag("golden") {
        "contract only"
    } else {
        "contract + schema + lint"
    };
    println!("analyze: clean ({checked} manifest(s), {scope})");
    Ok(())
}

fn list() -> Result<()> {
    let root = artifacts_root();
    if !root.exists() {
        bail!("no artifacts/ directory — run `make artifacts`");
    }
    let mut names: Vec<String> = std::fs::read_dir(&root)?
        .filter_map(|e| e.ok())
        .filter(|e| e.path().join("manifest.json").exists())
        .map(|e| e.file_name().to_string_lossy().into_owned())
        .collect();
    names.sort();
    for n in &names {
        println!("{n}");
    }
    info!("{} variants under {}", names.len(), root.display());
    Ok(())
}

fn info_cmd(args: &Args) -> Result<()> {
    let name = variant_arg(args)?;
    let bundle = Bundle::open(artifacts_root().join(&name))?;
    let m = &bundle.manifest;
    println!("variant:        {}", m.name);
    println!("param leaves:   {}", m.num_leaves());
    println!("total params:   {}", m.analysis.total_params);
    println!("active params:  {}", m.analysis.active_params);
    println!("fwd GFLOPs/tok: {:.4}", m.analysis.fwd_flops_per_token / 1e9);
    println!("batch x seq:    {} x {}", m.batch_size, m.seq_len);
    println!("eval lengths:   {:?}", m.eval_lens);
    println!("routers x experts: {} x {}", m.num_routers, m.num_experts);
    match &m.decode {
        Some(d) => println!(
            "decode:         batch {}, prefill lens {:?}, {} state leaves",
            d.batch,
            d.prefill_lens,
            d.state.len()
        ),
        None => println!("decode:         unavailable (no generation artifacts)"),
    }
    // Cross-check the rust FLOPS mirror against the python-emitted value.
    let cfg = rom::config::ModelCfg::parse(&m.model)?;
    let mirrored = rom::analysis::flops::flops_per_token(&cfg, m.seq_len)?;
    let rel = (mirrored - m.analysis.fwd_flops_per_token).abs()
        / m.analysis.fwd_flops_per_token;
    println!(
        "flops mirror:   {:.4} GF/tok (rel err {:.2e})",
        mirrored / 1e9,
        rel
    );
    Ok(())
}

fn train(args: &Args) -> Result<()> {
    let name = variant_arg(args)?;
    let bundle = Bundle::open(artifacts_root().join(&name))
        .with_context(|| format!("loading variant {name}"))?;
    let cfg = TrainCfg {
        steps: args.get_u64("steps", 300),
        max_lr: args.get_f64("lr", lr_budget()),
        warmup_ratio: args.get_f64("warmup", 0.01),
        data_seed: args.get_u64("seed", 0),
        grad_accum: args.has_flag("accum"),
        eval_every: args.get_u64("eval-every", 0),
        checkpoint_every: args.get_u64("ckpt-every", 0),
        log_every: args.get_u64("log-every", 20),
    };
    let mut trainer = Trainer::new(Arc::clone(&bundle), cfg);
    trainer.quiet = args.has_flag("quiet");
    trainer.dp = match args.get("dp") {
        Some(v) => Some(v.parse().context("--dp expects a replica count")?),
        None => dp_budget(),
    };
    if let Some(dir) = args.get("ckpt-dir") {
        trainer.checkpoint_dir = Some(dir.into());
    }
    if let Some(keep) = args.get("ckpt-keep") {
        trainer.checkpoint_keep =
            Some(keep.parse().context("--ckpt-keep expects a number")?);
    }
    let report = trainer.run()?;
    println!("final loss:     {:.4}", report.final_loss);
    println!("smoothed loss:  {:.4}", report.smoothed_loss);
    println!("throughput:     {:.0} tokens/s", report.tokens_per_sec);
    if let Some(dp) = &report.dp_stats {
        println!(
            "dp:             {} replica(s), shard step {:.1} ms, reduce {:.1} ms",
            dp.world, dp.shard_step_ms, dp.reduce_ms
        );
    }
    for (ctx, ppl) in &report.eval_ppl {
        println!("ppl@{ctx}:        {ppl:.3}");
    }
    println!(
        "expert balance: max/uniform {:.2}, entropy {:.3}",
        report.balance.max_over_uniform, report.balance.norm_entropy
    );
    if let Some(path) = args.get("metrics") {
        report.metrics.save(std::path::Path::new(path))?;
        info!("metrics written to {path}");
    }
    Ok(())
}

fn eval_cmd(args: &Args) -> Result<()> {
    let name = variant_arg(args)?;
    let ckpt_path = required_opt(args, "ckpt")?;
    let bundle = Bundle::open(artifacts_root().join(&name))?;
    let ck = Checkpoint::load(std::path::Path::new(ckpt_path))?;
    let sess = Session::restore(Arc::clone(&bundle), &ck.params, &ck.m, &ck.v, ck.step)?;
    let corpus = Corpus::new(CorpusSpec::default(), 17);
    for (ctx, ppl) in eval_ppl_sweep(&sess, &corpus, 999, 8)? {
        println!("ppl@{ctx}: {ppl:.3}");
    }
    Ok(())
}

/// `rom generate <variant> --ckpt FILE --prompt-tokens 1,2,3[;4,5,6]`:
/// restore a trained checkpoint and decode `--max-new` tokens per prompt.
/// Greedy by default; `--temperature X` (with optional `--top-k K`) samples
/// from a stream seeded by `--seed`, so reruns reproduce token for token.
fn generate_cmd(args: &Args) -> Result<()> {
    let name = variant_arg(args)?;
    let ckpt_path = required_opt(args, "ckpt")?;
    let prompts = parse_prompt_tokens(required_opt(args, "prompt-tokens")?)
        .map_err(usage_err)?;
    let gen_cfg = GenerateCfg {
        max_new: args.get_usize("max-new", 32),
        temperature: args.get_f64("temperature", 0.0),
        top_k: args.get_usize("top-k", 0),
        seed: args.get_u64("seed", 0),
    };
    let bundle = Bundle::open(artifacts_root().join(&name))
        .with_context(|| format!("loading variant {name}"))?;
    let ck = Checkpoint::load(std::path::Path::new(ckpt_path))?;
    let sess = Session::restore(Arc::clone(&bundle), &ck.params, &ck.m, &ck.v, ck.step)?;
    let report = generate(&sess, &prompts, &gen_cfg)?;

    for (i, (prompt, completion)) in
        prompts.iter().zip(report.completions.iter()).enumerate()
    {
        let fmt = |ts: &[i32]| {
            ts.iter().map(|t| t.to_string()).collect::<Vec<_>>().join(",")
        };
        println!("prompt {i}: {} => {}", fmt(prompt), fmt(completion));
    }
    let how = match report.prefill_artifact_tokens {
        0 => "decode_step fallback".to_string(),
        l if l == report.prompt_len => format!("prefill_L{l} artifact"),
        l => format!(
            "prefill_L{l} artifact + {} stepwise tail tokens",
            report.prompt_len - l
        ),
    };
    println!(
        "prefill:  {:.1} ms for {} prompt tokens ({how})",
        report.prefill_s * 1e3,
        report.prompt_len
    );
    if let (Some(ms), Some(tps)) =
        (report.median_decode_ms(), report.decode_tokens_per_sec())
    {
        println!(
            "decode:   {ms:.2} ms/step median, {tps:.0} tokens/s \
             (batch {} rows/step)",
            report.batch
        );
    }
    Ok(())
}

/// `rom serve <variant> --ckpt FILE [--requests FILE]`: the long-lived
/// continuous-batching loop. Request lines stream in from a file or stdin
/// on a reader thread over a bounded channel (so a slow decode loop
/// backpressures the producer instead of buffering unboundedly), the engine
/// pumps one batched decode step per iteration, and responses print as
/// sequences finish — not in admission order.
fn serve_cmd(args: &Args) -> Result<()> {
    let name = variant_arg(args)?;
    let ckpt_path = required_opt(args, "ckpt")?;
    let defaults = ServeRequest {
        prompt: Vec::new(),
        max_new: args.get_usize("max-new", 32),
        temperature: args.get_f64("temperature", 0.0),
        top_k: args.get_usize("top-k", 0),
        seed: args.get_u64("seed", 0),
        stop: args.get_opt("stop").map_err(usage_err)?,
    };
    let cfg = ServeCfg { queue_cap: args.get_usize("queue", 64) };
    let bundle = Bundle::open(artifacts_root().join(&name))
        .with_context(|| format!("loading variant {name}"))?;
    let ck = Checkpoint::load(std::path::Path::new(ckpt_path))?;
    let sess = Session::restore(Arc::clone(&bundle), &ck.params, &ck.m, &ck.v, ck.step)?;
    let mut engine = Engine::new(&sess, &cfg)?;

    let source: Box<dyn BufRead + Send> = match args.get("requests") {
        Some(p) => Box::new(std::io::BufReader::new(
            std::fs::File::open(p).with_context(|| format!("opening {p}"))?,
        )),
        None => Box::new(std::io::BufReader::new(std::io::stdin())),
    };
    let (rx, reader) = line_pump(source, cfg.queue_cap);

    let mut pending: VecDeque<ServeRequest> = VecDeque::new();
    let mut eof = false;
    while !(eof && pending.is_empty() && engine.idle()) {
        // Hand pending requests to the engine until it pushes back.
        while let Some(req) = pending.pop_front() {
            match engine.submit(req)? {
                Submit::Accepted(_) => {}
                Submit::Rejected(req) => {
                    pending.push_front(req);
                    break;
                }
            }
        }
        // Pull request lines: non-blocking while work is in flight, blocking
        // only when fully idle (nothing to do but wait for the next line).
        while pending.len() < cfg.queue_cap {
            let idle = engine.idle() && pending.is_empty();
            let line = if idle && !eof {
                rx.recv().ok()
            } else {
                match rx.try_recv() {
                    Ok(l) => Some(l),
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => None,
                }
            };
            match line {
                Some(l) => {
                    pending.extend(parse_request_line(&l, &defaults).map_err(usage_err)?)
                }
                None => {
                    eof = true;
                    break;
                }
            }
        }
        for resp in engine.step(&sess)? {
            let fmt = |ts: &[i32]| {
                ts.iter().map(|t| t.to_string()).collect::<Vec<_>>().join(",")
            };
            let finish = match resp.finish {
                FinishReason::Stop => "stop",
                FinishReason::MaxNew => "max-new",
                FinishReason::KvCapExhausted => "kv-cap",
            };
            println!(
                "req {}: {} => {} ({finish}; wait {:.1} ms, ttft {:.1} ms)",
                resp.id,
                fmt(&resp.prompt),
                fmt(&resp.tokens),
                resp.queue_wait_s * 1e3,
                resp.ttft_s * 1e3
            );
        }
    }
    reader
        .join()
        .map_err(|_| anyhow!("request reader thread panicked"))?
        .context("reading requests")?;

    let rep = engine.report();
    println!(
        "served:   {} requests, {} tokens, {} prefills, {} decode steps",
        rep.completed, rep.emitted_tokens, rep.prefills, rep.decode_steps
    );
    if let Some(t) = &rep.ttft {
        println!(
            "ttft:     p50 {:.1} ms, p90 {:.1} ms, max {:.1} ms",
            t.p50_ms, t.p90_ms, t.max_ms
        );
    }
    if let Some(t) = &rep.per_token {
        println!("token:    p50 {:.2} ms, p99 {:.2} ms", t.p50_ms, t.p99_ms);
    }
    Ok(())
}

fn probes(args: &Args) -> Result<()> {
    let name = variant_arg(args)?;
    let steps = args.get_u64("steps", 150);
    let bundle = Bundle::open(artifacts_root().join(&name))?;
    // Short training so probe scores are above chance — the same `Trainer`
    // loop as `rom train` (eval/checkpoint cadences off), which hands the
    // trained session back for scoring.
    let cfg = TrainCfg {
        steps,
        max_lr: args.get_f64("lr", lr_budget()),
        log_every: 0,
        ..TrainCfg::default()
    };
    let mut trainer = Trainer::new(Arc::clone(&bundle), cfg);
    trainer.quiet = true;
    trainer.final_eval = false; // probes below, not the PPL sweep
    let (_report, sess) = trainer.run_session()?;

    let corpus = Corpus::new(CorpusSpec::default(), 17);
    let ctx = bundle.manifest.eval_lens[0];
    let cloze = score_cloze(&sess, &make_cloze(&corpus, 7, 32, ctx))?;
    println!(
        "cloze   (n={}): acc {:.1}%  true-token ppl {:.2}",
        cloze.n,
        cloze.accuracy * 100.0,
        cloze.ppl()
    );
    let pre = ctx / 2;
    let cont = score_continuation(&sess, &make_continuation(&corpus, 8, 16, ctx - pre, pre))?;
    println!("contin. (n={}): acc {:.1}%", cont.n, cont.accuracy * 100.0);
    Ok(())
}

fn experiment(args: &Args) -> Result<()> {
    let id = variant_arg(args)?;
    let steps = args.get_u64("steps", 200);
    let jobs = args.get_usize("jobs", default_jobs(dp_budget()));
    let rep = run_experiment(&id, steps, jobs)?;
    rep.print();
    Ok(())
}
