//! `rom` — the RoM training coordinator CLI (the launcher of DESIGN.md §2).
//!
//! Subcommands:
//!   info <variant>                      manifest + analytic accounting
//!   train <variant> [--steps N] [--lr X] [--accum] [--ckpt-dir D]
//!                   [--ckpt-every N] [--ckpt-keep N] [--eval-every N]
//!                   [--log-every N] [--warmup R] [--metrics FILE]
//!   eval <variant> --ckpt FILE          PPL sweep from a checkpoint
//!   probes <variant> [--steps N] [--lr X]  downstream probe scores (Table 2)
//!   experiment <id> [--steps N] [--jobs N]  regenerate a paper table/figure
//!   list                                variants with artifacts present

use std::sync::Arc;

use anyhow::{bail, Context, Result};
use rom::config::TrainCfg;
use rom::coordinator::checkpoint::Checkpoint;
use rom::coordinator::downstream::{score_cloze, score_continuation};
use rom::coordinator::eval::eval_ppl_sweep;
use rom::coordinator::trainer::Trainer;
use rom::data::corpus::{Corpus, CorpusSpec};
use rom::data::probes::{make_cloze, make_continuation};
use rom::experiments::harness::{artifacts_root, lr_budget};
use rom::experiments::scheduler::default_jobs;
use rom::experiments::tables::run_experiment;
use rom::info;
use rom::runtime::artifact::Bundle;
use rom::runtime::session::Session;
use rom::substrate::cli::Args;

const USAGE: &str = "\
rom — Routing Mamba training coordinator
usage: rom <subcommand> [options]
  list                              show variants with artifacts
  info <variant>                    manifest + analytic accounting
  train <variant> [--steps N] [--lr X] [--warmup R] [--seed N] [--accum]
                  [--ckpt-dir D] [--ckpt-every N] [--ckpt-keep N]
                  [--eval-every N] [--log-every N] [--metrics FILE]
                  (--ckpt-keep N retains only the newest N checkpoints)
  eval <variant> --ckpt FILE        PPL sweep from a checkpoint
  probes <variant> [--steps N] [--lr X]
                                    downstream probes (Table 2 stand-in)
  experiment <id> [--steps N] [--jobs N]
                                    regenerate a table/figure
                                    (fig2 fig3 fig4 table1 table2 table3
                                     table6 table10 table11)
                                    --jobs N trains N variants in parallel
                                    (default from ROM_JOBS, else 1; rows are
                                    byte-identical to a serial run)
";

fn main() -> Result<()> {
    let args = Args::from_env(&["accum", "quiet"]);
    match args.subcommand.as_deref() {
        Some("list") => list(),
        Some("info") => info_cmd(&args),
        Some("train") => train(&args),
        Some("eval") => eval_cmd(&args),
        Some("probes") => probes(&args),
        Some("experiment") => experiment(&args),
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    }
}

fn variant_arg(args: &Args) -> Result<String> {
    args.positional
        .first()
        .cloned()
        .ok_or_else(|| anyhow::anyhow!("missing <variant> argument\n{USAGE}"))
}

fn list() -> Result<()> {
    let root = artifacts_root();
    if !root.exists() {
        bail!("no artifacts/ directory — run `make artifacts`");
    }
    let mut names: Vec<String> = std::fs::read_dir(&root)?
        .filter_map(|e| e.ok())
        .filter(|e| e.path().join("manifest.json").exists())
        .map(|e| e.file_name().to_string_lossy().into_owned())
        .collect();
    names.sort();
    for n in &names {
        println!("{n}");
    }
    info!("{} variants under {}", names.len(), root.display());
    Ok(())
}

fn info_cmd(args: &Args) -> Result<()> {
    let name = variant_arg(args)?;
    let bundle = Bundle::open(artifacts_root().join(&name))?;
    let m = &bundle.manifest;
    println!("variant:        {}", m.name);
    println!("param leaves:   {}", m.num_leaves());
    println!("total params:   {}", m.analysis.total_params);
    println!("active params:  {}", m.analysis.active_params);
    println!("fwd GFLOPs/tok: {:.4}", m.analysis.fwd_flops_per_token / 1e9);
    println!("batch x seq:    {} x {}", m.batch_size, m.seq_len);
    println!("eval lengths:   {:?}", m.eval_lens);
    println!("routers x experts: {} x {}", m.num_routers, m.num_experts);
    // Cross-check the rust FLOPS mirror against the python-emitted value.
    let cfg = rom::config::ModelCfg::parse(&m.model)?;
    let mirrored = rom::analysis::flops::flops_per_token(&cfg, m.seq_len)?;
    let rel = (mirrored - m.analysis.fwd_flops_per_token).abs()
        / m.analysis.fwd_flops_per_token;
    println!(
        "flops mirror:   {:.4} GF/tok (rel err {:.2e})",
        mirrored / 1e9,
        rel
    );
    Ok(())
}

fn train(args: &Args) -> Result<()> {
    let name = variant_arg(args)?;
    let bundle = Bundle::open(artifacts_root().join(&name))
        .with_context(|| format!("loading variant {name}"))?;
    let cfg = TrainCfg {
        steps: args.get_u64("steps", 300),
        max_lr: args.get_f64("lr", lr_budget()),
        warmup_ratio: args.get_f64("warmup", 0.01),
        data_seed: args.get_u64("seed", 0),
        grad_accum: args.has_flag("accum"),
        eval_every: args.get_u64("eval-every", 0),
        checkpoint_every: args.get_u64("ckpt-every", 0),
        log_every: args.get_u64("log-every", 20),
    };
    let mut trainer = Trainer::new(Arc::clone(&bundle), cfg);
    trainer.quiet = args.has_flag("quiet");
    if let Some(dir) = args.get("ckpt-dir") {
        trainer.checkpoint_dir = Some(dir.into());
    }
    if let Some(keep) = args.get("ckpt-keep") {
        trainer.checkpoint_keep =
            Some(keep.parse().context("--ckpt-keep expects a number")?);
    }
    let report = trainer.run()?;
    println!("final loss:     {:.4}", report.final_loss);
    println!("smoothed loss:  {:.4}", report.smoothed_loss);
    println!("throughput:     {:.0} tokens/s", report.tokens_per_sec);
    for (ctx, ppl) in &report.eval_ppl {
        println!("ppl@{ctx}:        {ppl:.3}");
    }
    println!(
        "expert balance: max/uniform {:.2}, entropy {:.3}",
        report.balance.max_over_uniform, report.balance.norm_entropy
    );
    if let Some(path) = args.get("metrics") {
        report.metrics.save(std::path::Path::new(path))?;
        info!("metrics written to {path}");
    }
    Ok(())
}

fn eval_cmd(args: &Args) -> Result<()> {
    let name = variant_arg(args)?;
    let ckpt_path = args
        .get("ckpt")
        .ok_or_else(|| anyhow::anyhow!("--ckpt FILE required"))?;
    let bundle = Bundle::open(artifacts_root().join(&name))?;
    let ck = Checkpoint::load(std::path::Path::new(ckpt_path))?;
    let sess = Session::restore(Arc::clone(&bundle), &ck.params, &ck.m, &ck.v, ck.step)?;
    let corpus = Corpus::new(CorpusSpec::default(), 17);
    for (ctx, ppl) in eval_ppl_sweep(&sess, &corpus, 999, 8)? {
        println!("ppl@{ctx}: {ppl:.3}");
    }
    Ok(())
}

fn probes(args: &Args) -> Result<()> {
    let name = variant_arg(args)?;
    let steps = args.get_u64("steps", 150);
    let bundle = Bundle::open(artifacts_root().join(&name))?;
    // Short training so probe scores are above chance — the same `Trainer`
    // loop as `rom train` (eval/checkpoint cadences off), which hands the
    // trained session back for scoring.
    let cfg = TrainCfg {
        steps,
        max_lr: args.get_f64("lr", lr_budget()),
        log_every: 0,
        ..TrainCfg::default()
    };
    let mut trainer = Trainer::new(Arc::clone(&bundle), cfg);
    trainer.quiet = true;
    trainer.final_eval = false; // probes below, not the PPL sweep
    let (_report, sess) = trainer.run_session()?;

    let corpus = Corpus::new(CorpusSpec::default(), 17);
    let ctx = bundle.manifest.eval_lens[0];
    let cloze = score_cloze(&sess, &make_cloze(&corpus, 7, 32, ctx))?;
    println!(
        "cloze   (n={}): acc {:.1}%  true-token ppl {:.2}",
        cloze.n,
        cloze.accuracy * 100.0,
        cloze.ppl()
    );
    let pre = ctx / 2;
    let cont = score_continuation(&sess, &make_continuation(&corpus, 8, 16, ctx - pre, pre))?;
    println!("contin. (n={}): acc {:.1}%", cont.n, cont.accuracy * 100.0);
    Ok(())
}

fn experiment(args: &Args) -> Result<()> {
    let id = variant_arg(args)?;
    let steps = args.get_u64("steps", 200);
    let jobs = args.get_usize("jobs", default_jobs());
    let rep = run_experiment(&id, steps, jobs)?;
    rep.print();
    Ok(())
}
