//! Config mirror of python/compile/config.py. The same JSON drives both
//! sides; rust parses it for sizing, FLOPS accounting and experiment
//! orchestration (it never builds the model itself — that is baked into the
//! artifacts).

use anyhow::{bail, Context, Result};

use crate::substrate::json::Json;

#[derive(Debug, Clone, PartialEq)]
pub struct MoECfg {
    pub num_experts: usize,
    pub top_k: usize,
    pub jitter: f64,
    pub balance_loss: f64,
}

impl Default for MoECfg {
    fn default() -> Self {
        MoECfg { num_experts: 1, top_k: 1, jitter: 0.0, balance_loss: 0.0 }
    }
}

impl MoECfg {
    pub fn enabled(&self) -> bool {
        self.num_experts > 1
    }

    fn parse(j: &Json) -> Result<MoECfg> {
        Ok(MoECfg {
            num_experts: j.get("num_experts")?.as_usize()?,
            top_k: j.get("top_k")?.as_usize()?,
            jitter: j.get("jitter")?.as_f64()?,
            balance_loss: j.get("balance_loss")?.as_f64()?,
        })
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct ModelCfg {
    pub name: String,
    pub arch: String,
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub expand: usize,
    pub d_state: usize,
    pub dt_rank: usize,
    pub conv_kernel: usize,
    pub n_heads: usize,
    pub window: usize,
    pub mlp_mult: usize,
    pub rom_targets: Vec<String>,
    pub routing: String,
    pub rom: MoECfg,
    pub ffn_moe: MoECfg,
    pub ffn_moe_share_router: bool,
    pub attn_moe: String,
    pub attn_moe_experts: usize,
    pub batch_size: usize,
    pub seq_len: usize,
    pub eval_lens: Vec<usize>,
}

impl ModelCfg {
    pub fn parse(j: &Json) -> Result<ModelCfg> {
        let j = if j.opt("model").is_some() { j.get("model")? } else { j };
        Ok(ModelCfg {
            name: j.get("name")?.as_str()?.to_string(),
            arch: j.get("arch")?.as_str()?.to_string(),
            vocab_size: j.get("vocab_size")?.as_usize()?,
            d_model: j.get("d_model")?.as_usize()?,
            n_layers: j.get("n_layers")?.as_usize()?,
            expand: j.get("expand")?.as_usize()?,
            d_state: j.get("d_state")?.as_usize()?,
            dt_rank: j.get("dt_rank")?.as_usize()?,
            conv_kernel: j.get("conv_kernel")?.as_usize()?,
            n_heads: j.get("n_heads")?.as_usize()?,
            window: j.get("window")?.as_usize()?,
            mlp_mult: j.get("mlp_mult")?.as_usize()?,
            rom_targets: j
                .get("rom_targets")?
                .as_arr()?
                .iter()
                .map(|v| Ok(v.as_str()?.to_string()))
                .collect::<Result<_>>()?,
            routing: j.get("routing")?.as_str()?.to_string(),
            rom: MoECfg::parse(j.get("rom")?)?,
            ffn_moe: MoECfg::parse(j.get("ffn_moe")?)?,
            ffn_moe_share_router: j.get("ffn_moe_share_router")?.as_bool()?,
            attn_moe: j.get("attn_moe")?.as_str()?.to_string(),
            attn_moe_experts: j.get("attn_moe_experts")?.as_usize()?,
            batch_size: j.get("batch_size")?.as_usize()?,
            seq_len: j.get("seq_len")?.as_usize()?,
            eval_lens: j
                .get("eval_lens")?
                .as_arr()?
                .iter()
                .map(|v| v.as_usize())
                .collect::<Result<_, _>>()?,
        })
    }

    pub fn load(path: &std::path::Path) -> Result<ModelCfg> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        ModelCfg::parse(&Json::parse(&text)?)
    }

    pub fn d_inner(&self) -> usize {
        self.expand * self.d_model
    }

    /// Decode KV-cache capacity for full-attention blocks (window <= 0):
    /// 2x the longest context any artifact is built for. Mirrors the python
    /// `ModelConfig.kv_cap` derived property — a function of seq_len and
    /// eval_lens, never a stored config field — and is what the manifest's
    /// `decode.kv_cap` must equal for full-attention layouts.
    pub fn kv_cap(&self) -> usize {
        2 * self.eval_lens.iter().copied().chain([self.seq_len]).max().unwrap_or(self.seq_len)
    }

    /// Per-layer block kinds — mirrors ModelConfig.block_layout().
    pub fn block_layout(&self) -> Result<Vec<&'static str>> {
        let mut out = Vec::new();
        match self.arch.as_str() {
            "mamba" => out.extend(std::iter::repeat_n("mamba", self.n_layers)),
            "mamba2" => out.extend(std::iter::repeat_n("mamba2", self.n_layers)),
            "gdn" => out.extend(std::iter::repeat_n("gdn", self.n_layers)),
            "samba" => {
                for _ in 0..self.n_layers {
                    out.extend(["mamba", "swa", "mlp"]);
                }
            }
            "llama" => {
                for _ in 0..self.n_layers {
                    out.extend(["swa", "mlp"]);
                }
            }
            other => bail!("unknown arch {other:?}"),
        }
        Ok(out)
    }
}

/// Training hyperparameters owned by the coordinator (the artifact only sees
/// the per-step lr scalar).
#[derive(Debug, Clone)]
pub struct TrainCfg {
    pub steps: u64,
    pub max_lr: f64,
    pub warmup_ratio: f64,
    pub data_seed: u64,
    pub grad_accum: bool,
    pub eval_every: u64,
    pub checkpoint_every: u64,
    pub log_every: u64,
}

impl Default for TrainCfg {
    fn default() -> Self {
        // Paper §5.1: cosine schedule, max lr 4e-4, warmup ratio 0.01.
        TrainCfg {
            steps: 300,
            max_lr: 4e-4,
            warmup_ratio: 0.01,
            data_seed: 0,
            grad_accum: false,
            eval_every: 0,
            checkpoint_every: 0,
            log_every: 20,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"{
      "name": "x", "arch": "samba", "vocab_size": 512, "d_model": 96,
      "n_layers": 2, "expand": 2, "d_state": 16, "dt_rank": 6,
      "conv_kernel": 4, "n_heads": 4, "window": 64, "mlp_mult": 2,
      "tie_embeddings": true, "rom_targets": ["conv", "gate", "out"],
      "routing": "shared",
      "rom": {"num_experts": 8, "top_k": 1, "jitter": 0.0,
              "balance_loss": 0.0, "straight_through": true},
      "ffn_moe": {"num_experts": 1, "top_k": 1, "jitter": 0.0,
                  "balance_loss": 0.0, "straight_through": true},
      "ffn_moe_share_router": false,
      "attn_moe": "none", "attn_moe_experts": 8,
      "moe_impl": "onehot", "scan_impl": "assoc",
      "batch_size": 8, "seq_len": 128, "micro_batch": 0,
      "eval_lens": [128, 256, 512]
    }"#;

    #[test]
    fn parses_full_config() {
        let cfg = ModelCfg::parse(&Json::parse(DOC).unwrap()).unwrap();
        assert_eq!(cfg.arch, "samba");
        assert_eq!(cfg.rom.num_experts, 8);
        assert!(cfg.rom.enabled());
        assert!(!cfg.ffn_moe.enabled());
        assert_eq!(cfg.rom_targets, vec!["conv", "gate", "out"]);
        assert_eq!(cfg.d_inner(), 192);
    }

    #[test]
    fn block_layouts_mirror_python() {
        let mut cfg = ModelCfg::parse(&Json::parse(DOC).unwrap()).unwrap();
        assert_eq!(
            cfg.block_layout().unwrap(),
            vec!["mamba", "swa", "mlp", "mamba", "swa", "mlp"]
        );
        cfg.arch = "mamba".into();
        assert_eq!(cfg.block_layout().unwrap(), vec!["mamba", "mamba"]);
        cfg.arch = "llama".into();
        assert_eq!(cfg.block_layout().unwrap(), vec!["swa", "mlp", "swa", "mlp"]);
    }

    #[test]
    fn kv_cap_mirrors_python_derivation() {
        let mut cfg = ModelCfg::parse(&Json::parse(DOC).unwrap()).unwrap();
        assert_eq!(cfg.kv_cap(), 1024); // 2 * max(eval_lens=[128,256,512], 128)
        cfg.eval_lens = vec![64];
        assert_eq!(cfg.kv_cap(), 256); // seq_len 128 dominates
        cfg.eval_lens.clear();
        assert_eq!(cfg.kv_cap(), 256);
    }

    #[test]
    fn wrapped_model_doc() {
        let wrapped = format!(r#"{{"model": {DOC}, "train": {{}}}}"#);
        let cfg = ModelCfg::parse(&Json::parse(&wrapped).unwrap()).unwrap();
        assert_eq!(cfg.name, "x");
    }
}
