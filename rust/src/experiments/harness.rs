//! Shared experiment plumbing: train a variant's artifact bundle on the
//! synthetic corpus, evaluate PPL at every context length, and collect the
//! paper-table columns (active/total params, FLOPS, PPL@len...).
//!
//! Every bench_* target and `rom experiment <id>` row goes through
//! `run_variant_spec`, so table rows are produced identically everywhere —
//! including under the parallel scheduler (`experiments::scheduler`), whose
//! workers call it with nothing shared between variants: each call opens its
//! own PJRT client and bundle, which is what makes variant fan-out safe
//! without any assumption about PJRT handle thread-affinity.

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::config::TrainCfg;
use crate::coordinator::trainer::Trainer;
use crate::runtime::artifact::Bundle;
use crate::warnln;

pub fn artifacts_root() -> PathBuf {
    // target/ binaries run from the workspace root; override via env.
    if let Ok(p) = std::env::var("ROM_ARTIFACTS") {
        return PathBuf::from(p);
    }
    PathBuf::from("artifacts")
}

pub fn have_variant(name: &str) -> bool {
    artifacts_root().join(name).join("manifest.json").exists()
}

/// Optional comma-separated variant filter (ROM_VARIANT_FILTER) so partial
/// table rows can be regenerated without the full sweep's wall-clock.
fn filtered_out(name: &str) -> bool {
    match std::env::var("ROM_VARIANT_FILTER") {
        Ok(f) if !f.is_empty() => !f.split(',').any(|v| v.trim() == name),
        _ => false,
    }
}

/// Drop missing/filtered variants (with a warn per skip) and return the
/// runnable names in input order — the one skip path shared by every table
/// and example that feeds a sweep.
pub fn runnable_variants(variants: &[&str]) -> Vec<String> {
    let mut out = Vec::with_capacity(variants.len());
    for name in variants {
        if !have_variant(name) || filtered_out(name) {
            warnln!("skipping {name}: artifacts missing or filtered");
            continue;
        }
        out.push(name.to_string());
    }
    out
}

#[derive(Debug, Clone)]
pub struct VariantResult {
    pub name: String,
    pub active_params: u64,
    pub total_params: u64,
    pub flops_per_token: f64,
    pub final_loss: f64,
    pub smoothed_loss: f64,
    pub tokens_per_sec: f64,
    /// (ctx_len, ppl) at every eval length of the bundle.
    pub ppl: Vec<(usize, f64)>,
    pub balance_max_over_uniform: f64,
    pub balance_entropy: f64,
}

impl VariantResult {
    pub fn ppl_at(&self, ctx: usize) -> Option<f64> {
        self.ppl.iter().find(|(c, _)| *c == ctx).map(|(_, p)| *p)
    }

    pub fn fmt_params(n: u64) -> String {
        if n >= 1_000_000 {
            format!("{:.2}M", n as f64 / 1e6)
        } else {
            format!("{:.0}K", n as f64 / 1e3)
        }
    }
}

/// How to run one variant row. `RunSpec::new` gives the table defaults
/// (fused path, final PPL sweep on, normal logging); benches and probe runs
/// flip the fields they need.
#[derive(Debug, Clone)]
pub struct RunSpec {
    pub steps: u64,
    pub max_lr: f64,
    pub grad_accum: bool,
    /// Run the final multi-length PPL sweep (tables need it; wall-clock
    /// benches don't).
    pub final_eval: bool,
    pub quiet: bool,
    /// Data-parallel replica count per variant (`--dp`/ROM_DP). `None`
    /// keeps the classic single-client paths; `Some(k)` routes the run
    /// through the dp driver, which shards each variant's loader across
    /// `k` PJRT clients and reduces gradients host-side.
    pub dp: Option<usize>,
}

impl RunSpec {
    pub fn new(steps: u64, max_lr: f64) -> RunSpec {
        RunSpec { steps, max_lr, grad_accum: false, final_eval: true, quiet: false, dp: None }
    }
}

/// The workhorse behind every table row: train `spec.steps` optimizer steps
/// on the shared synthetic corpus and return the table columns (`max_lr` is
/// typically lr_budget() = 3e-3, scaled up from the paper's 4e-4 because
/// the models are ~100x smaller — see EXPERIMENTS.md). Self-contained per
/// call (fresh client + bundle), so it is safe to run from any scheduler
/// worker; every caller goes through here or `scheduler::run_sweep`.
pub fn run_variant_spec(name: &str, spec: &RunSpec) -> Result<VariantResult> {
    let bundle = Bundle::open(artifacts_root().join(name))
        .with_context(|| format!("variant {name} (run `make artifacts`)"))?;
    let train_cfg = TrainCfg {
        steps: spec.steps,
        max_lr: spec.max_lr,
        grad_accum: spec.grad_accum,
        log_every: (spec.steps / 5).max(1),
        ..TrainCfg::default()
    };
    let mut trainer = Trainer::new(Arc::clone(&bundle), train_cfg);
    trainer.quiet = spec.quiet;
    trainer.final_eval = spec.final_eval;
    trainer.dp = spec.dp;
    let report = trainer.run()?;
    let man = &bundle.manifest;
    Ok(VariantResult {
        name: name.to_string(),
        active_params: man.analysis.active_params,
        total_params: man.analysis.total_params,
        flops_per_token: man.analysis.fwd_flops_per_token,
        final_loss: report.final_loss,
        smoothed_loss: report.smoothed_loss,
        tokens_per_sec: report.tokens_per_sec,
        ppl: report.eval_ppl,
        balance_max_over_uniform: report.balance.max_over_uniform,
        balance_entropy: report.balance.norm_entropy,
    })
}

/// Step budget for experiment rows; overridable via ROM_STEPS to trade
/// fidelity for wall-clock (benches use smaller defaults than `rom experiment`).
pub fn step_budget(default: u64) -> u64 {
    std::env::var("ROM_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

pub fn lr_budget() -> f64 {
    std::env::var("ROM_LR")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(3e-3)
}

/// Data-parallel fan-out for experiment/bench runs: ROM_DP parsed to a
/// replica count (`Some(k)` for k >= 1, `None` when unset or garbage).
/// `Some(1)` is meaningful — it runs the dp driver's one-replica baseline
/// rather than the classic fused path, which is what the dp bit-identity
/// comparisons pin against.
pub fn dp_budget() -> Option<usize> {
    std::env::var("ROM_DP").ok().and_then(|s| s.parse::<usize>().ok()).filter(|&k| k >= 1)
}
