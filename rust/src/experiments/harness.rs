//! Shared experiment plumbing: train a variant's artifact bundle on the
//! synthetic corpus, evaluate PPL at every context length, and collect the
//! paper-table columns (active/total params, FLOPS, PPL@len...).
//!
//! Every bench_* target and `rom experiment <id>` row goes through
//! `run_variant`, so table rows are produced identically everywhere.

use std::path::PathBuf;
use std::rc::Rc;

use anyhow::{Context, Result};

use crate::config::TrainCfg;
use crate::coordinator::trainer::Trainer;
use crate::runtime::artifact::{cpu_client, Bundle};

pub fn artifacts_root() -> PathBuf {
    // target/ binaries run from the workspace root; override via env.
    if let Ok(p) = std::env::var("ROM_ARTIFACTS") {
        return PathBuf::from(p);
    }
    PathBuf::from("artifacts")
}

pub fn have_variant(name: &str) -> bool {
    artifacts_root().join(name).join("manifest.json").exists()
}

#[derive(Debug, Clone)]
pub struct VariantResult {
    pub name: String,
    pub active_params: u64,
    pub total_params: u64,
    pub flops_per_token: f64,
    pub final_loss: f64,
    pub smoothed_loss: f64,
    pub tokens_per_sec: f64,
    /// (ctx_len, ppl) at every eval length of the bundle.
    pub ppl: Vec<(usize, f64)>,
    pub balance_max_over_uniform: f64,
    pub balance_entropy: f64,
}

impl VariantResult {
    pub fn ppl_at(&self, ctx: usize) -> Option<f64> {
        self.ppl.iter().find(|(c, _)| *c == ctx).map(|(_, p)| *p)
    }

    pub fn fmt_params(n: u64) -> String {
        if n >= 1_000_000 {
            format!("{:.2}M", n as f64 / 1e6)
        } else {
            format!("{:.0}K", n as f64 / 1e3)
        }
    }
}

/// Train `steps` optimizer steps on the shared synthetic corpus and return
/// the table columns. `max_lr` is typically lr_budget() = 3e-3 (scaled up
/// from the paper's 4e-4 because the models are ~100x smaller — see
/// EXPERIMENTS.md).
pub fn run_variant(name: &str, steps: u64, max_lr: f64) -> Result<VariantResult> {
    let client = cpu_client()?;
    run_variant_with(client, name, steps, max_lr, false)
}

pub fn run_variant_with(
    client: Rc<xla::PjRtClient>,
    name: &str,
    steps: u64,
    max_lr: f64,
    grad_accum: bool,
) -> Result<VariantResult> {
    let bundle = Bundle::load(client, artifacts_root().join(name))
        .with_context(|| format!("variant {name} (run `make artifacts`)"))?;
    let train_cfg = TrainCfg {
        steps,
        max_lr,
        grad_accum,
        log_every: (steps / 5).max(1),
        ..TrainCfg::default()
    };
    let trainer = Trainer::new(&bundle, train_cfg);
    let report = trainer.run()?;
    let man = &bundle.manifest;
    Ok(VariantResult {
        name: name.to_string(),
        active_params: man.analysis.active_params,
        total_params: man.analysis.total_params,
        flops_per_token: man.analysis.fwd_flops_per_token,
        final_loss: report.final_loss,
        smoothed_loss: report.smoothed_loss,
        tokens_per_sec: report.tokens_per_sec,
        ppl: report.eval_ppl,
        balance_max_over_uniform: report.balance.max_over_uniform,
        balance_entropy: report.balance.norm_entropy,
    })
}

/// Step budget for experiment rows; overridable via ROM_STEPS to trade
/// fidelity for wall-clock (benches use smaller defaults than `rom experiment`).
pub fn step_budget(default: u64) -> u64 {
    std::env::var("ROM_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

pub fn lr_budget() -> f64 {
    std::env::var("ROM_LR")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(3e-3)
}
