//! Experiment harness: one module per paper table/figure (DESIGN.md §4).
pub mod harness;
pub mod tables;
