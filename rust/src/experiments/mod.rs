//! Experiment harness: one module per paper table/figure (DESIGN.md §4),
//! plus the parallel sweep scheduler that fans independent variants out
//! across worker threads.
pub mod harness;
pub mod scheduler;
pub mod tables;
