//! Parallel experiment scheduler: fan independent variant runs out over the
//! substrate thread pool with deterministic result ordering and per-job
//! error isolation.
//!
//! The paper's headline results are sweeps — Fig 2 alone trains nine
//! variants; the scaling ladders train eight more — and every variant is
//! independent: its own PJRT client, its own bundle, its own corpus streams.
//! `run_jobs` exploits exactly that independence and nothing more:
//!
//! * **Nothing thread-affine crosses a thread.** A job closure receives only
//!   the variant name (plus `Send` captures) and constructs client + bundle
//!   + session on its worker thread (`Bundle::open`). This is the
//!   one-client-per-worker fallback of the runtime's ownership model (see
//!   `runtime::artifact` docs) and stays correct even though the PJRT FFI
//!   wrapper does not declare its handles `Send`.
//! * **Deterministic ordering.** Results come back indexed and are returned
//!   in submission order, so a `--jobs 4` sweep emits byte-identical table
//!   rows to `--jobs 1` (each variant's training is itself deterministic —
//!   the pipelined-vs-synchronous guard pins that).
//! * **Error isolation.** A job that fails — `Err` or panic — yields an
//!   `Err` in its slot; the remaining jobs run to completion. Panics are
//!   caught inside the job so a poisoned variant can never wedge the pool's
//!   in-flight accounting (a panicking pool worker would otherwise leave
//!   `join` waiting forever).

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::channel;
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::experiments::harness::{run_variant_spec, RunSpec, VariantResult};
use crate::substrate::pool::{panic_message, ThreadPool};
use crate::warnln;

/// Default worker count for sweeps: the ROM_JOBS env var, else 1 (serial —
/// parallelism is opt-in because concurrent variants share the machine's
/// cores with XLA's own intra-op threads), divided by the run's
/// data-parallel fan-out: every variant job spawns `dp` replicas of its
/// own, so `--jobs J x --dp K` would oversubscribe the cores K-fold if the
/// default ignored it. Pass the resolved `--dp`/ROM_DP value (`None` = 1).
pub fn default_jobs(dp: Option<usize>) -> usize {
    compose_jobs(parse_jobs(std::env::var("ROM_JOBS").ok().as_deref()), dp.unwrap_or(1))
}

/// The scheduler's share of the core budget once each job fans out into
/// `dp` replicas: `jobs / dp`, floored to one worker.
fn compose_jobs(jobs: usize, dp: usize) -> usize {
    (jobs / dp.max(1)).max(1)
}

fn parse_jobs(v: Option<&str>) -> usize {
    v.and_then(|s| s.parse::<usize>().ok()).map(|n| n.max(1)).unwrap_or(1)
}

/// Run `f` once per item on `workers` pool threads (serially when
/// `workers <= 1` — the same closure either way, so both paths produce
/// identical results). Returns one `Result` per item, in item order.
pub fn run_jobs<T, F>(items: &[String], workers: usize, f: F) -> Vec<Result<T>>
where
    T: Send + 'static,
    F: Fn(usize, &str) -> Result<T> + Send + Sync + 'static,
{
    let guarded = move |idx: usize, name: &str| -> Result<T> {
        match catch_unwind(AssertUnwindSafe(|| f(idx, name))) {
            Ok(res) => res,
            Err(payload) => {
                Err(anyhow!("job '{name}' panicked: {}", panic_message(payload.as_ref())))
            }
        }
    };
    if workers <= 1 || items.len() <= 1 {
        return items.iter().enumerate().map(|(i, name)| guarded(i, name)).collect();
    }

    let guarded = Arc::new(guarded);
    let pool = ThreadPool::new(workers.min(items.len()));
    let (tx, rx) = channel::<(usize, Result<T>)>();
    for (idx, name) in items.iter().enumerate() {
        let g = Arc::clone(&guarded);
        let tx = tx.clone();
        let name = name.clone();
        pool.submit(move || {
            let _ = tx.send((idx, (*g)(idx, &name)));
        });
    }
    drop(tx); // the receiver loop below ends when the last job's clone drops

    let mut slots: Vec<Option<Result<T>>> = items.iter().map(|_| None).collect();
    for (idx, res) in rx {
        slots[idx] = Some(res);
    }
    slots
        .into_iter()
        .map(|s| s.expect("scheduler lost a job result"))
        .collect()
}

/// Pair each item name with its job result, warn-log every failure (error
/// isolation means a failed row costs only itself), and keep the successes
/// in submission order. Returns `(successes, failure_count)` — callers must
/// propagate a nonzero failure count as an error once they have shown the
/// surviving rows, so an experiment with broken variants cannot exit 0
/// silently. The one failure-reporting path shared by every table/example
/// that consumes `run_jobs`/`run_sweep` output.
pub fn collect_ok<T>(names: &[String], results: Vec<Result<T>>) -> (Vec<(String, T)>, usize) {
    let mut failed = 0usize;
    let ok = names
        .iter()
        .zip(results)
        .filter_map(|(name, res)| match res {
            Ok(r) => Some((name.clone(), r)),
            Err(e) => {
                warnln!("{name} failed (other rows unaffected): {e:#}");
                failed += 1;
                None
            }
        })
        .collect();
    (ok, failed)
}

/// Train every variant under one `RunSpec` across `workers` threads; one
/// `Result` per variant, in variant order. This is the engine behind
/// `rom experiment <id> --jobs N` and the bench sweep section.
pub fn run_sweep(
    variants: &[String],
    spec: &RunSpec,
    workers: usize,
) -> Vec<Result<VariantResult>> {
    let spec = spec.clone();
    run_jobs(variants, workers, move |_idx, name| run_variant_spec(name, &spec))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn items(names: &[&str]) -> Vec<String> {
        names.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parallel_results_match_serial_in_order() {
        let work = items(&["a", "bb", "ccc", "dddd", "eeeee", "ffffff", "g", "hh"]);
        let f = |idx: usize, name: &str| -> Result<String> {
            // Stagger so completion order differs from submission order.
            std::thread::sleep(std::time::Duration::from_millis(
                ((work_len(name) * 7 + idx) % 5) as u64,
            ));
            Ok(format!("{idx}:{name}:{}", work_len(name)))
        };
        fn work_len(s: &str) -> usize {
            s.len()
        }
        let serial: Vec<String> =
            run_jobs(&work, 1, f).into_iter().map(|r| r.unwrap()).collect();
        let parallel: Vec<String> =
            run_jobs(&work, 4, f).into_iter().map(|r| r.unwrap()).collect();
        assert_eq!(serial, parallel);
        assert_eq!(serial[2], "2:ccc:3");
    }

    #[test]
    fn failing_job_does_not_poison_others() {
        let work = items(&["ok1", "bad", "ok2", "ok3"]);
        let results = run_jobs(&work, 3, |_i, name| {
            if name == "bad" {
                anyhow::bail!("artifact missing for {name}");
            }
            Ok(name.to_string())
        });
        assert_eq!(results.len(), 4);
        assert_eq!(results[0].as_ref().unwrap(), "ok1");
        assert!(results[1].as_ref().unwrap_err().to_string().contains("artifact missing"));
        assert_eq!(results[2].as_ref().unwrap(), "ok2");
        assert_eq!(results[3].as_ref().unwrap(), "ok3");
    }

    #[test]
    fn panicking_job_is_isolated_and_pool_survives() {
        let work = items(&["fine", "explodes", "also-fine"]);
        let results = run_jobs(&work, 2, |_i, name| {
            if name == "explodes" {
                panic!("variant blew up");
            }
            Ok(name.len())
        });
        assert_eq!(results[0].as_ref().unwrap(), &4);
        let err = results[1].as_ref().unwrap_err().to_string();
        assert!(err.contains("panicked") && err.contains("variant blew up"), "got: {err}");
        assert_eq!(results[2].as_ref().unwrap(), &9);
    }

    #[test]
    fn serial_path_isolates_panics_too() {
        let work = items(&["explodes", "fine"]);
        let results = run_jobs(&work, 1, |_i, name| {
            if name == "explodes" {
                panic!("boom");
            }
            Ok(())
        });
        assert!(results[0].is_err());
        assert!(results[1].is_ok());
    }

    #[test]
    fn collect_ok_reports_failures_and_keeps_order() {
        let names = items(&["a", "b", "c"]);
        let results: Vec<Result<u32>> = vec![Ok(1), Err(anyhow!("nope")), Ok(3)];
        let (ok, failed) = collect_ok(&names, results);
        assert_eq!(failed, 1);
        assert_eq!(ok, vec![("a".to_string(), 1), ("c".to_string(), 3)]);
    }

    #[test]
    fn jobs_parse_defaults_and_clamps() {
        assert_eq!(parse_jobs(None), 1);
        assert_eq!(parse_jobs(Some("4")), 4);
        assert_eq!(parse_jobs(Some("0")), 1);
        assert_eq!(parse_jobs(Some("not-a-number")), 1);
    }

    #[test]
    fn jobs_divide_by_dp_factor() {
        // --jobs x --dp must never oversubscribe: the default worker count
        // hands each dp replica a core from the same budget.
        assert_eq!(compose_jobs(8, 2), 4);
        assert_eq!(compose_jobs(8, 3), 2);
        assert_eq!(compose_jobs(4, 8), 1); // floored, never zero workers
        assert_eq!(compose_jobs(5, 1), 5);
        assert_eq!(compose_jobs(3, 0), 3); // dp 0 is treated as 1
    }

    #[test]
    fn empty_item_list_is_fine() {
        let results: Vec<Result<()>> = run_jobs(&[], 4, |_i, _n| Ok(()));
        assert!(results.is_empty());
    }
}
