//! One function per paper table/figure (DESIGN.md §4 experiment index).
//!
//! Shape, not absolute numbers: every row is produced on the scaled-down
//! substitution workload (synthetic corpus, tiny ladder), so the comparisons
//! that matter are orderings and rough ratios — who wins, by how much,
//! where the crossovers sit. `rom experiment <id>` runs the full budget;
//! bench targets run a reduced ROM_STEPS budget.
//!
//! Sweeps fan out across `jobs` scheduler workers (`--jobs N` / ROM_JOBS);
//! rows are emitted in variant order regardless of completion order. ROM_DP
//! additionally runs every variant data-parallel (`dp_budget`), with the
//! default worker count divided down so jobs x replicas never oversubscribe. A
//! failing variant costs only its own row — every sibling still runs and its
//! row still prints — but the experiment then exits nonzero (`seal_table`),
//! so a sweep with broken variants can never read as a silent success.
//! Table 11 is the exception to parallelism: it measures per-variant
//! throughput, which concurrent training would corrupt, so it always runs
//! serially.

use std::sync::Arc;

use anyhow::Result;

use crate::config::TrainCfg;
use crate::coordinator::downstream::{score_cloze, score_continuation};
use crate::coordinator::trainer::Trainer;
use crate::data::corpus::{Corpus, CorpusSpec};
use crate::data::probes::{make_cloze, make_continuation};
use crate::experiments::harness::{
    artifacts_root, dp_budget, lr_budget, runnable_variants, step_budget, RunSpec, VariantResult,
};
use crate::experiments::scheduler::{collect_ok, run_jobs, run_sweep};
use crate::info;
use crate::runtime::artifact::Bundle;
use crate::substrate::bench::Reporter;

fn ppl_cols(r: &VariantResult) -> Vec<String> {
    r.ppl.iter().map(|(_, p)| format!("{p:.3}")).collect()
}

/// Seal a table after a sweep: with zero failures, hand the reporter back
/// for the caller to print; otherwise print the surviving rows here and
/// surface the failure count as an error so `rom experiment` / bench targets
/// exit nonzero (row isolation shows partial results; it must not convert a
/// broken sweep into a silent success).
fn seal_table(rep: Reporter, failed: usize) -> Result<Reporter> {
    if failed == 0 {
        return Ok(rep);
    }
    rep.print();
    anyhow::bail!("{failed} variant job(s) failed — surviving rows printed above")
}

/// Shared sweep-to-rows driver behind fig2/fig3/fig4/table1/table3/table10.
/// Public so the scheduler determinism guard in the integration tests can
/// compare the exact rows `--jobs 1` and `--jobs N` produce.
pub fn run_rows(title: &str, variants: &[&str], steps: u64, jobs: usize) -> Result<Reporter> {
    let mut rep = Reporter::new(
        title,
        &["variant", "active", "total", "GFLOPs/tok", "loss", "ppl@128", "ppl@256", "ppl@512"],
    );
    let names = runnable_variants(variants);
    let mut spec = RunSpec::new(steps, lr_budget());
    spec.dp = dp_budget();
    let (rows, failed) = collect_ok(&names, run_sweep(&names, &spec, jobs));
    for (_name, r) in rows {
        let mut row = vec![
            r.name.clone(),
            VariantResult::fmt_params(r.active_params),
            VariantResult::fmt_params(r.total_params),
            format!("{:.4}", r.flops_per_token / 1e9),
            format!("{:.3}", r.smoothed_loss),
        ];
        row.extend(ppl_cols(&r));
        while row.len() < 8 {
            row.push("-".into());
        }
        rep.row(&row[..8]);
        info!("{} done: loss {:.3}", r.name, r.smoothed_loss);
    }
    seal_table(rep, failed)
}

/// Fig 2 / Table 4: naive MoE-Mamba combos degrade Samba; shared-routing RoM
/// improves it at the same total parameters.
pub fn fig2(steps_default: u64, jobs: usize) -> Result<Reporter> {
    run_rows(
        "Fig 2 / Table 4 — naive MoE-Mamba vs RoM on Samba (PPL lower=better)",
        &[
            "samba-e2",
            "samba-e2-moemamba-c",
            "samba-e2-moemamba-g",
            "samba-e2-moemamba-o",
            "samba-e2-moemamba-cg",
            "samba-e2-moemamba-co",
            "samba-e2-moemamba-go",
            "samba-e2-moemamba-cgo",
            "samba-e2-rom",
        ],
        step_budget(steps_default),
        jobs,
    )
}

/// Fig 3: PPL vs active-parameter ladder, dense Mamba vs RoM.
pub fn fig3(steps_default: u64, jobs: usize) -> Result<Reporter> {
    run_rows(
        "Fig 3 — scaling ladder: dense Mamba vs RoM (1/8 experts)",
        &[
            "mamba-tiny", "rom-tiny",
            "mamba-small", "rom-small",
            "mamba-base", "rom-base",
            "mamba-large", "rom-large",
        ],
        step_budget(steps_default),
        jobs,
    )
}

/// Fig 4 / Tables 7-9: eval-length extrapolation (PPL at 128/256/512 for
/// models trained at T=128). The multi-length columns of fig3's rows ARE this
/// figure; kept separate so the bench target exists per the experiment index.
pub fn fig4(steps_default: u64, jobs: usize) -> Result<Reporter> {
    run_rows(
        "Fig 4 / Tables 7-9 — length extrapolation (train T=128, eval 128/256/512)",
        &["mamba-tiny", "rom-tiny", "mamba-small", "rom-small"],
        step_budget(steps_default),
        jobs,
    )
}

/// Table 1: architecture comparison.
pub fn table1(steps_default: u64, jobs: usize) -> Result<Reporter> {
    run_rows(
        "Table 1 — architectures (Llama proxy, Mamba, Samba, attention-MoE, RoM)",
        &[
            "llama",
            "mamba-t1",
            "samba-e2",
            "samba-e2-moa",
            "samba-e2-switchhead",
            "samba-e2-moemamba-cgo",
            "samba-e2-rom",
            "samba-e4",
            "samba-e4-rom-go",
            "samba-e4-rom",
            "samba-e4-rom-all",
        ],
        step_budget(steps_default),
        jobs,
    )
}

/// Table 3: RoM on other linear recurrent architectures.
pub fn table3(steps_default: u64, jobs: usize) -> Result<Reporter> {
    run_rows(
        "Table 3 — RoM on Mamba / Mamba2 / Gated DeltaNet",
        &[
            "mamba-small", "rom-small",
            "mamba2-small", "mamba2-small-rom",
            "gdn-small", "gdn-small-rom",
        ],
        step_budget(steps_default),
        jobs,
    )
}

/// Table 6: load-balance-loss ablation + natural balance diagnostics.
pub fn table6(steps_default: u64, jobs: usize) -> Result<Reporter> {
    let mut rep = Reporter::new(
        "Table 6 — load balance ablation (RoM balances naturally)",
        &["variant", "ppl@128", "ppl@512", "max/uniform", "norm-entropy"],
    );
    let names = runnable_variants(&[
        "samba-e4",
        "samba-e4-rom",
        "samba-e4-rom-bal",
        "samba-e4-rom-all",
        "samba-e4-rom-all-bal",
    ]);
    let mut spec = RunSpec::new(step_budget(steps_default), lr_budget());
    spec.dp = dp_budget();
    let (rows, failed) = collect_ok(&names, run_sweep(&names, &spec, jobs));
    for (_name, r) in rows {
        rep.row(&[
            r.name.clone(),
            r.ppl_at(128).map(|p| format!("{p:.3}")).unwrap_or_else(|| "-".into()),
            r.ppl_at(512).map(|p| format!("{p:.3}")).unwrap_or_else(|| "-".into()),
            format!("{:.2}", r.balance_max_over_uniform),
            format!("{:.3}", r.balance_entropy),
        ]);
    }
    seal_table(rep, failed)
}

/// Table 10: hybrid RoM+FFN-MoE vs FFN-MoE perplexity.
pub fn table10(steps_default: u64, jobs: usize) -> Result<Reporter> {
    run_rows(
        "Table 10 — FFN-MoE vs hybrid RoM+FFN-MoE",
        &["samba-e4", "samba-ffnmoe16", "samba-rom-ffnmoe8"],
        step_budget(steps_default),
        jobs,
    )
}

/// Table 2: downstream probes (cloze + continuation choice). Each variant
/// trains via the shared `Trainer` (same loop as `rom train`) and scores
/// probes on the returned session; variants fan out across scheduler workers.
pub fn table2(steps_default: u64, jobs: usize) -> Result<Reporter> {
    let mut rep = Reporter::new(
        "Table 2 — downstream probes (cloze acc / PPL, continuation acc)",
        &["variant", "active", "total", "cloze-ppl", "cloze-acc%", "cont-acc%"],
    );
    let names = runnable_variants(&["samba-e4", "samba-ffnmoe16", "samba-rom-ffnmoe8"]);
    let steps = step_budget(steps_default);
    let lr = lr_budget();
    let results = run_jobs(&names, jobs, move |_idx, name| table2_row(name, steps, lr));
    let (rows, failed) = collect_ok(&names, results);
    for (_name, row) in rows {
        rep.row(&row);
    }
    seal_table(rep, failed)
}

/// One table2 row: train with the shared Trainer (probes need the trained
/// session, so this uses `run_session`), then score cloze + continuation.
fn table2_row(name: &str, steps: u64, max_lr: f64) -> Result<Vec<String>> {
    let bundle = Bundle::open(artifacts_root().join(name))?;
    let cfg = TrainCfg { steps, max_lr, log_every: 0, ..TrainCfg::default() };
    let mut trainer = Trainer::new(Arc::clone(&bundle), cfg);
    trainer.quiet = true;
    trainer.final_eval = false; // probes below, not the PPL sweep
    trainer.dp = dp_budget();
    let (_report, sess) = trainer.run_session()?;

    let corpus = Corpus::new(CorpusSpec::default(), 17);
    let ctx = bundle.manifest.eval_lens[0];
    let cloze = score_cloze(&sess, &make_cloze(&corpus, 7, 24, ctx))?;
    let pre = ctx / 2;
    let cont =
        score_continuation(&sess, &make_continuation(&corpus, 8, 16, ctx - pre, pre))?;
    let man = &bundle.manifest;
    Ok(vec![
        name.to_string(),
        VariantResult::fmt_params(man.analysis.active_params),
        VariantResult::fmt_params(man.analysis.total_params),
        format!("{:.2}", cloze.ppl()),
        format!("{:.1}", cloze.accuracy * 100.0),
        format!("{:.1}", cont.accuracy * 100.0),
    ])
}

/// Table 11: training throughput — RoM vs dense at equal active params vs
/// width expansion. Few steps; throughput is steady-state tokens/s.
/// ALWAYS serial (ignores `jobs`): concurrent variants would contend for
/// cores and corrupt the tokens/s comparison the table exists to make.
pub fn table11(steps_default: u64, _jobs: usize) -> Result<Reporter> {
    let mut rep = Reporter::new(
        "Table 11 — training throughput (tokens/s, identical hardware)",
        &["variant", "active", "total", "tok/s", "rel%"],
    );
    let names = runnable_variants(&["samba-e2", "samba-e2-rom", "samba-e4"]);
    let mut spec = RunSpec::new(step_budget(steps_default), lr_budget());
    spec.dp = dp_budget();
    let (rows, failed) = collect_ok(&names, run_sweep(&names, &spec, 1));
    // rel% is pinned to the table's designated baseline — the FIRST runnable
    // variant. If that row failed there is no denominator, so rel% prints
    // "-" instead of silently rebasing to the next surviving variant.
    let baseline = names.first().cloned();
    let base_rate = rows
        .iter()
        .find(|(n, _)| Some(n) == baseline.as_ref())
        .map(|(_, r)| r.tokens_per_sec);
    for (_name, r) in rows {
        rep.row(&[
            r.name.clone(),
            VariantResult::fmt_params(r.active_params),
            VariantResult::fmt_params(r.total_params),
            format!("{:.0}", r.tokens_per_sec),
            base_rate
                .map(|b| format!("{:.0}", 100.0 * r.tokens_per_sec / b))
                .unwrap_or_else(|| "-".into()),
        ]);
    }
    seal_table(rep, failed)
}

/// Dispatch by experiment id (DESIGN.md §4). `jobs` is the scheduler worker
/// count (1 = serial; table11 is always serial regardless).
pub fn run_experiment(id: &str, steps_default: u64, jobs: usize) -> Result<Reporter> {
    match id {
        "fig2" => fig2(steps_default, jobs),
        "fig3" => fig3(steps_default, jobs),
        "fig4" => fig4(steps_default, jobs),
        "table1" => table1(steps_default, jobs),
        "table2" => table2(steps_default, jobs),
        "table3" => table3(steps_default, jobs),
        "table6" => table6(steps_default, jobs),
        "table10" => table10(steps_default, jobs),
        "table11" => table11(steps_default, jobs),
        other => anyhow::bail!(
            "unknown experiment {other}; ids: fig2 fig3 fig4 table1 table2 table3 table6 table10 table11"
        ),
    }
}
