//! One function per paper table/figure (DESIGN.md §4 experiment index).
//!
//! Shape, not absolute numbers: every row is produced on the scaled-down
//! substitution workload (synthetic corpus, tiny ladder), so the comparisons
//! that matter are orderings and rough ratios — who wins, by how much,
//! where the crossovers sit. `rom experiment <id>` runs the full budget;
//! bench targets run a reduced ROM_STEPS budget.

use anyhow::Result;

use crate::coordinator::downstream::{score_cloze, score_continuation};
use crate::data::corpus::{Corpus, CorpusSpec};
use crate::data::probes::{make_cloze, make_continuation};
use crate::experiments::harness::{
    artifacts_root, have_variant, lr_budget, run_variant, step_budget, VariantResult,
};
use crate::runtime::artifact::{cpu_client, Bundle};
use crate::runtime::session::Session;
use crate::substrate::bench::Reporter;
use crate::{info, warnln};

fn ppl_cols(r: &VariantResult) -> Vec<String> {
    r.ppl.iter().map(|(_, p)| format!("{p:.3}")).collect()
}

/// Optional comma-separated variant filter (ROM_VARIANT_FILTER) so partial
/// table rows can be regenerated without the full sweep's wall-clock.
fn filtered_out(name: &str) -> bool {
    match std::env::var("ROM_VARIANT_FILTER") {
        Ok(f) if !f.is_empty() => !f.split(',').any(|v| v.trim() == name),
        _ => false,
    }
}

fn run_rows(title: &str, variants: &[&str], steps: u64) -> Result<Reporter> {
    let mut rep = Reporter::new(
        title,
        &["variant", "active", "total", "GFLOPs/tok", "loss", "ppl@128", "ppl@256", "ppl@512"],
    );
    for name in variants {
        if !have_variant(name) || filtered_out(name) {
            warnln!("skipping {name}: artifacts missing or filtered");
            continue;
        }
        let r = run_variant(name, steps, lr_budget())?;
        let mut row = vec![
            r.name.clone(),
            VariantResult::fmt_params(r.active_params),
            VariantResult::fmt_params(r.total_params),
            format!("{:.4}", r.flops_per_token / 1e9),
            format!("{:.3}", r.smoothed_loss),
        ];
        row.extend(ppl_cols(&r));
        while row.len() < 8 {
            row.push("-".into());
        }
        rep.row(&row[..8].to_vec());
        info!("{} done: loss {:.3}", r.name, r.smoothed_loss);
    }
    Ok(rep)
}

/// Fig 2 / Table 4: naive MoE-Mamba combos degrade Samba; shared-routing RoM
/// improves it at the same total parameters.
pub fn fig2(steps_default: u64) -> Result<Reporter> {
    run_rows(
        "Fig 2 / Table 4 — naive MoE-Mamba vs RoM on Samba (PPL lower=better)",
        &[
            "samba-e2",
            "samba-e2-moemamba-c",
            "samba-e2-moemamba-g",
            "samba-e2-moemamba-o",
            "samba-e2-moemamba-cg",
            "samba-e2-moemamba-co",
            "samba-e2-moemamba-go",
            "samba-e2-moemamba-cgo",
            "samba-e2-rom",
        ],
        step_budget(steps_default),
    )
}

/// Fig 3: PPL vs active-parameter ladder, dense Mamba vs RoM.
pub fn fig3(steps_default: u64) -> Result<Reporter> {
    run_rows(
        "Fig 3 — scaling ladder: dense Mamba vs RoM (1/8 experts)",
        &[
            "mamba-tiny", "rom-tiny",
            "mamba-small", "rom-small",
            "mamba-base", "rom-base",
            "mamba-large", "rom-large",
        ],
        step_budget(steps_default),
    )
}

/// Fig 4 / Tables 7-9: eval-length extrapolation (PPL at 128/256/512 for
/// models trained at T=128). The multi-length columns of fig3's rows ARE this
/// figure; kept separate so the bench target exists per the experiment index.
pub fn fig4(steps_default: u64) -> Result<Reporter> {
    run_rows(
        "Fig 4 / Tables 7-9 — length extrapolation (train T=128, eval 128/256/512)",
        &["mamba-tiny", "rom-tiny", "mamba-small", "rom-small"],
        step_budget(steps_default),
    )
}

/// Table 1: architecture comparison.
pub fn table1(steps_default: u64) -> Result<Reporter> {
    run_rows(
        "Table 1 — architectures (Llama proxy, Mamba, Samba, attention-MoE, RoM)",
        &[
            "llama",
            "mamba-t1",
            "samba-e2",
            "samba-e2-moa",
            "samba-e2-switchhead",
            "samba-e2-moemamba-cgo",
            "samba-e2-rom",
            "samba-e4",
            "samba-e4-rom-go",
            "samba-e4-rom",
            "samba-e4-rom-all",
        ],
        step_budget(steps_default),
    )
}

/// Table 3: RoM on other linear recurrent architectures.
pub fn table3(steps_default: u64) -> Result<Reporter> {
    run_rows(
        "Table 3 — RoM on Mamba / Mamba2 / Gated DeltaNet",
        &[
            "mamba-small", "rom-small",
            "mamba2-small", "mamba2-small-rom",
            "gdn-small", "gdn-small-rom",
        ],
        step_budget(steps_default),
    )
}

/// Table 6: load-balance-loss ablation + natural balance diagnostics.
pub fn table6(steps_default: u64) -> Result<Reporter> {
    let mut rep = Reporter::new(
        "Table 6 — load balance ablation (RoM balances naturally)",
        &["variant", "ppl@128", "ppl@512", "max/uniform", "norm-entropy"],
    );
    for name in [
        "samba-e4",
        "samba-e4-rom",
        "samba-e4-rom-bal",
        "samba-e4-rom-all",
        "samba-e4-rom-all-bal",
    ] {
        if !have_variant(name) || filtered_out(name) {
            warnln!("skipping {name}: artifacts missing");
            continue;
        }
        let r = run_variant(name, step_budget(steps_default), lr_budget())?;
        rep.row(&[
            r.name.clone(),
            r.ppl_at(128).map(|p| format!("{p:.3}")).unwrap_or("-".into()),
            r.ppl_at(512).map(|p| format!("{p:.3}")).unwrap_or("-".into()),
            format!("{:.2}", r.balance_max_over_uniform),
            format!("{:.3}", r.balance_entropy),
        ]);
    }
    Ok(rep)
}

/// Table 10: hybrid RoM+FFN-MoE vs FFN-MoE perplexity.
pub fn table10(steps_default: u64) -> Result<Reporter> {
    run_rows(
        "Table 10 — FFN-MoE vs hybrid RoM+FFN-MoE",
        &["samba-e4", "samba-ffnmoe16", "samba-rom-ffnmoe8"],
        step_budget(steps_default),
    )
}

/// Table 2: downstream probes (cloze + continuation choice).
pub fn table2(steps_default: u64) -> Result<Reporter> {
    let mut rep = Reporter::new(
        "Table 2 — downstream probes (cloze acc / PPL, continuation acc)",
        &["variant", "active", "total", "cloze-ppl", "cloze-acc%", "cont-acc%"],
    );
    let corpus = Corpus::new(CorpusSpec::default(), 17);
    let steps = step_budget(steps_default);
    for name in ["samba-e4", "samba-ffnmoe16", "samba-rom-ffnmoe8"] {
        if !have_variant(name) || filtered_out(name) {
            warnln!("skipping {name}: artifacts missing");
            continue;
        }
        // Train inline (the probe needs the trained session).
        let client = cpu_client()?;
        let bundle = Bundle::load(client, artifacts_root().join(name))?;
        let mut sess = Session::init(&bundle, 0)?;
        quick_train(&mut sess, &bundle, steps)?;
        let ctx = bundle.manifest.eval_lens[0];
        let cloze = score_cloze(&sess, &make_cloze(&corpus, 7, 24, ctx))?;
        let pre = ctx / 2;
        let cont = score_continuation(
            &sess,
            &make_continuation(&corpus, 8, 16, ctx - pre, pre),
        )?;
        let man = &bundle.manifest;
        rep.row(&[
            name.to_string(),
            VariantResult::fmt_params(man.analysis.active_params),
            VariantResult::fmt_params(man.analysis.total_params),
            format!("{:.2}", cloze.ppl()),
            format!("{:.1}", cloze.accuracy * 100.0),
            format!("{:.1}", cont.accuracy * 100.0),
        ]);
    }
    Ok(rep)
}

fn quick_train(sess: &mut Session, bundle: &Bundle, steps: u64) -> Result<()> {
    use crate::coordinator::schedule::CosineSchedule;
    use crate::data::loader::Loader;
    let man = &bundle.manifest;
    let corpus = Corpus::new(CorpusSpec::default(), 17);
    let stream = corpus.generate(0, (steps as usize + 2) * man.batch_size * (man.seq_len + 1));
    let mut loader = Loader::new(stream, man.batch_size, man.seq_len, 0);
    let sched = CosineSchedule::new(lr_budget(), steps, 0.01);
    for s in 1..=steps {
        let b = loader.next_batch();
        sess.train_step(sched.lr(s) as f32, &b.tokens, &b.targets)?;
    }
    Ok(())
}

/// Table 11: training throughput — RoM vs dense at equal active params vs
/// width expansion. Few steps; throughput is steady-state tokens/s.
pub fn table11(steps_default: u64) -> Result<Reporter> {
    let mut rep = Reporter::new(
        "Table 11 — training throughput (tokens/s, identical hardware)",
        &["variant", "active", "total", "tok/s", "rel%"],
    );
    let steps = step_budget(steps_default);
    let mut base_rate: Option<f64> = None;
    for name in ["samba-e2", "samba-e2-rom", "samba-e4"] {
        if !have_variant(name) || filtered_out(name) {
            warnln!("skipping {name}: artifacts missing");
            continue;
        }
        let r = run_variant(name, steps, lr_budget())?;
        if base_rate.is_none() {
            base_rate = Some(r.tokens_per_sec);
        }
        rep.row(&[
            r.name.clone(),
            VariantResult::fmt_params(r.active_params),
            VariantResult::fmt_params(r.total_params),
            format!("{:.0}", r.tokens_per_sec),
            format!("{:.0}", 100.0 * r.tokens_per_sec / base_rate.unwrap()),
        ]);
    }
    Ok(rep)
}

/// Dispatch by experiment id (DESIGN.md §4).
pub fn run_experiment(id: &str, steps_default: u64) -> Result<Reporter> {
    match id {
        "fig2" => fig2(steps_default),
        "fig3" => fig3(steps_default),
        "fig4" => fig4(steps_default),
        "table1" => table1(steps_default),
        "table2" => table2(steps_default),
        "table3" => table3(steps_default),
        "table6" => table6(steps_default),
        "table10" => table10(steps_default),
        "table11" => table11(steps_default),
        other => anyhow::bail!(
            "unknown experiment {other}; ids: fig2 fig3 fig4 table1 table2 table3 table6 table10 table11"
        ),
    }
}
