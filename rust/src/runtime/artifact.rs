//! Artifact bundles: manifest parsing + lazy compilation of the HLO-text
//! programs emitted by `python -m compile.aot` (DESIGN.md §2 contract).
//!
//! A bundle directory holds init/step/grad/apply/eval_L*.hlo.txt plus
//! manifest.json. Executables are compiled on first use and cached for the
//! life of the bundle (compilation is seconds; steps are milliseconds).
//!
//! Ownership model: everything here is shared-ownership (`Arc`) with a
//! `Mutex`-guarded program cache, so bundles, programs and the sessions built
//! on them are lifetime-free and ready to move across worker threads the
//! moment the PJRT FFI wrapper declares its handles `Send`. Until it does,
//! the experiment scheduler uses the safe fallback sanctioned by the design:
//! one PJRT client (and bundle) per worker thread — `Bundle::open` is the
//! one-call constructor each worker uses, and nothing thread-affine ever
//! crosses a thread boundary.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Context, Result};

use crate::runtime::tensor::{DType, Tensor};
use crate::substrate::json::Json;

/// One parameter leaf as recorded by the python manifest (flat order is the
/// calling convention for every artifact).
#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Analytic accounting mirrored from python/compile/analysis.py.
#[derive(Debug, Clone, Default)]
pub struct Analysis {
    pub total_params: u64,
    pub active_params: u64,
    pub fwd_flops_per_token: f64,
}

/// The generation-artifact calling convention (manifest "decode" section):
/// batch rows baked into `prefill_L{L}`/`decode_step`, the prefill lengths
/// emitted, and the flat recurrent-state layout (leaf 0 is always the i32
/// `pos` scalar). Mirrors `python/compile/decode.py::state_spec`.
#[derive(Debug, Clone)]
pub struct DecodeSpec {
    pub batch: usize,
    pub prefill_lens: Vec<usize>,
    /// Capacity of the full-attention KV-cache lanes (`window <= 0` swa
    /// blocks: the llama proxy and attn+SSM hybrids). `None` for rolling-
    /// window SWA and pure-SSM layouts. A decode step at position `pos`
    /// scatter-writes cache slot `pos`, so the coordinator must stop a
    /// request before `pos` reaches the cap — XLA clamps out-of-range
    /// dynamic-update indices, which would silently overwrite slot cap-1.
    pub kv_cap: Option<usize>,
    pub state: Vec<ParamSpec>,
}

impl DecodeSpec {
    /// Zeroed state tensors matching the spec (pos = 0) — the start-of-
    /// sequence generation state.
    pub fn zero_state(&self) -> Vec<Tensor> {
        self.state.iter().map(|s| Tensor::zeros(&s.shape, s.dtype)).collect()
    }

    /// Whether any state leaf belongs to a block that reads the shared `pos`
    /// scalar (SWA rolling KV caches use it for RoPE rotation and cache-
    /// validity masking). Pure-SSM layouts carry `pos` but never read it, so
    /// their rows can sit at different sequence positions inside one batched
    /// decode_step — the property slot-based continuous batching relies on.
    /// Position-dependent layouts must keep every batch row at the same
    /// position (gang admission in the serve engine).
    pub fn position_dependent(&self) -> bool {
        self.state.iter().any(|s| {
            s.name.ends_with(".k_cache") || s.name.ends_with(".v_cache")
        })
    }
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub name: String,
    pub params: Vec<ParamSpec>,
    pub batch_size: usize,
    pub seq_len: usize,
    pub micro_batch: usize,
    pub eval_lens: Vec<usize>,
    pub num_routers: usize,
    pub num_experts: usize,
    pub vocab_size: usize,
    pub analysis: Analysis,
    /// Present when the variant ships generation artifacts; `None` for
    /// variants that cannot carry fixed-shape decode state (the manifest's
    /// `decode_unsupported` field records why) and for legacy bundles
    /// lowered before the decoding subsystem existed.
    pub decode: Option<DecodeSpec>,
    pub model: Json,
}

/// Parse a `[{name, shape, dtype}, ...]` JSON array into leaf specs (shared
/// by the param manifest and the decode-state spec).
fn parse_specs(j: &Json) -> Result<Vec<ParamSpec>> {
    j.as_arr()?
        .iter()
        .map(|p| {
            Ok(ParamSpec {
                name: p.get("name")?.as_str()?.to_string(),
                shape: p
                    .get("shape")?
                    .as_arr()?
                    .iter()
                    .map(|d| d.as_usize())
                    .collect::<Result<_, _>>()?,
                dtype: DType::from_str(p.get("dtype")?.as_str()?)?,
            })
        })
        .collect()
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).context("manifest.json")?;
        let params = parse_specs(j.get("params")?)?;
        let decode = match j.opt("decode") {
            Some(d) => Some(DecodeSpec {
                batch: d.get("batch")?.as_usize()?,
                prefill_lens: d
                    .get("prefill_lens")?
                    .as_arr()?
                    .iter()
                    .map(|v| v.as_usize())
                    .collect::<Result<_, _>>()?,
                kv_cap: match d.opt("kv_cap") {
                    Some(v) => Some(v.as_usize().context("decode.kv_cap")?),
                    None => None,
                },
                state: parse_specs(d.get("state")?)?,
            }),
            None => None,
        };
        let a = j.get("analysis")?;
        Ok(Manifest {
            name: j.get("name")?.as_str()?.to_string(),
            params,
            batch_size: j.get("batch_size")?.as_usize()?,
            seq_len: j.get("seq_len")?.as_usize()?,
            micro_batch: j.get("micro_batch")?.as_usize()?,
            eval_lens: j
                .get("eval_lens")?
                .as_arr()?
                .iter()
                .map(|v| v.as_usize())
                .collect::<Result<_, _>>()?,
            num_routers: j.get("num_routers")?.as_usize()?,
            num_experts: j.get("num_experts")?.as_usize()?,
            vocab_size: j.get("model")?.get("vocab_size")?.as_usize()?,
            analysis: Analysis {
                total_params: a.get("total_params")?.as_i64()? as u64,
                active_params: a.get("active_params")?.as_i64()? as u64,
                fwd_flops_per_token: a.get("fwd_flops_per_token")?.as_f64()?,
            },
            decode,
            model: j.get("model")?.clone(),
        })
    }

    pub fn num_leaves(&self) -> usize {
        self.params.len()
    }

    /// Zeroed optimizer-state tensors matching the param leaves.
    pub fn zeros_like_params(&self) -> Vec<Tensor> {
        self.params
            .iter()
            .map(|p| Tensor::zeros(&p.shape, p.dtype))
            .collect()
    }
}

/// A compiled program + its expected output arity (for tuple decomposition).
pub struct Program {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl Program {
    /// Execute with literal inputs; returns the decomposed output tuple.
    /// (All artifacts are lowered with return_tuple=True — the single tuple
    /// buffer is fetched to host and decomposed; see DESIGN.md §6 L3 notes.)
    pub fn run(&self, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let outs = self
            .exe
            .execute::<&xla::Literal>(inputs)
            .with_context(|| format!("execute {}", self.name))?;
        let lit = outs[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }
}

/// Lazily compiled artifact bundle for one model variant. Shared-ownership:
/// hand out `Arc<Bundle>` and clone freely; the program cache is interior-
/// mutable behind a `Mutex` so `program()` works through `&self` from any
/// holder of the Arc.
pub struct Bundle {
    pub manifest: Manifest,
    pub dir: PathBuf,
    client: Arc<xla::PjRtClient>,
    cache: Mutex<BTreeMap<String, Arc<Program>>>,
}

impl Bundle {
    pub fn load(client: Arc<xla::PjRtClient>, dir: impl AsRef<Path>) -> Result<Bundle> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json", dir.display()))?;
        let manifest = Manifest::parse(&text)?;
        Ok(Bundle { manifest, dir, client, cache: Mutex::new(BTreeMap::new()) })
    }

    /// One-call constructor: open a bundle on a fresh CPU PJRT client and
    /// wrap it for shared ownership. This is the per-worker entry point the
    /// scheduler uses (one client per worker — see module docs).
    pub fn open(dir: impl AsRef<Path>) -> Result<Arc<Bundle>> {
        Ok(Arc::new(Bundle::load(cpu_client()?, dir)?))
    }

    /// Compile (or fetch cached) one program of this bundle by artifact stem.
    ///
    /// The cache lock is NOT held across compilation (which takes seconds):
    /// on a miss the lock is dropped, the program compiles, and the result is
    /// inserted with first-writer-wins semantics — a concurrent compile of
    /// the same stem wastes one compilation but every caller ends up sharing
    /// the same cached executable.
    pub fn program(&self, stem: &str) -> Result<Arc<Program>> {
        if let Some(p) = self.cache.lock().expect("program cache poisoned").get(stem) {
            return Ok(Arc::clone(p));
        }
        let path = self.dir.join(format!("{stem}.hlo.txt"));
        if !path.exists() {
            bail!("artifact {} missing (run `make artifacts`)", path.display());
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        let prog = Arc::new(Program { exe, name: format!("{}:{stem}", self.manifest.name) });
        let mut cache = self.cache.lock().expect("program cache poisoned");
        let cached = cache.entry(stem.to_string()).or_insert_with(|| Arc::clone(&prog));
        Ok(Arc::clone(cached))
    }

    pub fn init(&self) -> Result<Arc<Program>> {
        self.program("init")
    }
    pub fn step(&self) -> Result<Arc<Program>> {
        self.program("step")
    }
    pub fn grad(&self) -> Result<Arc<Program>> {
        self.program("grad")
    }
    pub fn apply(&self) -> Result<Arc<Program>> {
        self.program("apply")
    }
    pub fn eval(&self, len: usize) -> Result<Arc<Program>> {
        if !self.manifest.eval_lens.contains(&len) {
            bail!(
                "no eval artifact for length {len}; have {:?}",
                self.manifest.eval_lens
            );
        }
        self.program(&format!("eval_L{len}"))
    }

    /// Final-position-only NLL (emitted for eval_lens[0]; cloze probes).
    pub fn eval_last(&self, len: usize) -> Result<Arc<Program>> {
        self.program(&format!("eval_last_L{len}"))
    }

    /// The decode calling convention, or a clear error for variants without
    /// generation artifacts (unsupported layout or pre-decode bundles).
    pub fn decode_spec(&self) -> Result<&DecodeSpec> {
        self.manifest.decode.as_ref().ok_or_else(|| {
            anyhow!(
                "variant {} has no generation artifacts — re-run `make artifacts` \
                 (or the layout cannot carry fixed-shape decode state; see the \
                 manifest's decode_unsupported field)",
                self.manifest.name
            )
        })
    }

    /// Prompt-consumption program for an exact prefill length.
    pub fn prefill(&self, len: usize) -> Result<Arc<Program>> {
        let spec = self.decode_spec()?;
        if !spec.prefill_lens.contains(&len) {
            bail!(
                "no prefill artifact for length {len}; have {:?} \
                 (other prompt lengths go through the decode_step fallback)",
                spec.prefill_lens
            );
        }
        self.program(&format!("prefill_L{len}"))
    }

    /// One-token decode step program.
    pub fn decode_step(&self) -> Result<Arc<Program>> {
        self.decode_spec()?;
        self.program("decode_step")
    }

    /// Golden losses recorded by `compile.aot --golden` (if present).
    pub fn golden(&self) -> Result<Option<(u64, f64, Vec<f64>)>> {
        let path = self.dir.join("golden.json");
        if !path.exists() {
            return Ok(None);
        }
        let j = Json::parse(&std::fs::read_to_string(path)?)?;
        let losses = j
            .get("losses")?
            .as_arr()?
            .iter()
            .map(|v| v.as_f64())
            .collect::<Result<_, _>>()?;
        Ok(Some((
            j.get("data_seed")?.as_i64()? as u64,
            j.get("lr")?.as_f64()?,
            losses,
        )))
    }
}

/// Open a CPU PJRT client under shared ownership. Workers that run variants
/// concurrently each open their own client (see module docs).
pub fn cpu_client() -> Result<Arc<xla::PjRtClient>> {
    Ok(Arc::new(xla::PjRtClient::cpu()?))
}

#[cfg(test)]
mod tests {
    use super::*;

    const MANIFEST: &str = r#"{
      "name": "t", "batch_size": 2, "seq_len": 16, "micro_batch": 1,
      "eval_lens": [16, 32], "num_routers": 1, "num_experts": 8,
      "params": [
        {"name": "embed", "shape": [64, 32], "dtype": "float32"},
        {"name": "blocks.0.w_in", "shape": [8, 32, 64], "dtype": "float32"}
      ],
      "num_param_leaves": 2,
      "analysis": {"total_params": 18432, "active_params": 4096,
                   "fwd_flops_per_token": 1000.0, "fwd_flops_seq": 16000.0},
      "model": {"vocab_size": 64}
    }"#;

    #[test]
    fn manifest_parses() {
        let m = Manifest::parse(MANIFEST).unwrap();
        assert_eq!(m.name, "t");
        assert_eq!(m.num_leaves(), 2);
        assert_eq!(m.params[1].shape, vec![8, 32, 64]);
        assert_eq!(m.params[1].numel(), 8 * 32 * 64);
        assert_eq!(m.eval_lens, vec![16, 32]);
        assert_eq!(m.vocab_size, 64);
        assert_eq!(m.analysis.total_params, 18432);
    }

    #[test]
    fn zeros_like_params_shapes() {
        let m = Manifest::parse(MANIFEST).unwrap();
        let z = m.zeros_like_params();
        assert_eq!(z.len(), 2);
        assert_eq!(z[0].len(), 64 * 32);
        assert_eq!(z[1].shape, vec![8, 32, 64]);
    }

    #[test]
    fn manifest_rejects_missing_fields() {
        assert!(Manifest::parse("{}").is_err());
    }

    #[test]
    fn manifest_without_decode_section_parses_as_none() {
        // Legacy bundles (and unsupported layouts, which write null) carry
        // no decode spec; parsing must degrade, not fail.
        let m = Manifest::parse(MANIFEST).unwrap();
        assert!(m.decode.is_none());
        let with_null = MANIFEST.replacen(
            "\"name\": \"t\",",
            "\"name\": \"t\", \"decode\": null,",
            1,
        );
        assert!(Manifest::parse(&with_null).unwrap().decode.is_none());
    }

    #[test]
    fn manifest_decode_section_parses() {
        let with_decode = MANIFEST.replacen(
            "\"name\": \"t\",",
            r#""name": "t",
            "decode": {
              "batch": 2, "prefill_lens": [16, 32],
              "state": [
                {"name": "pos", "shape": [], "dtype": "int32"},
                {"name": "blocks.0.conv", "shape": [2, 3, 64], "dtype": "float32"},
                {"name": "blocks.0.ssm", "shape": [2, 64, 16], "dtype": "float32"}
              ]
            },"#,
            1,
        );
        let m = Manifest::parse(&with_decode).unwrap();
        let d = m.decode.as_ref().unwrap();
        assert_eq!(d.batch, 2);
        assert_eq!(d.prefill_lens, vec![16, 32]);
        // Pre-kv_cap decode sections (and null) parse as uncapped.
        assert_eq!(d.kv_cap, None);
        assert_eq!(d.state.len(), 3);
        assert_eq!(d.state[0].name, "pos");
        // conv+ssm lanes never read `pos`; a KV-cache leaf flips the bit.
        assert!(!d.position_dependent());
        let mut swa = d.clone();
        swa.state.push(ParamSpec {
            name: "blocks.1.k_cache".into(),
            shape: vec![2, 8, 64],
            dtype: DType::F32,
        });
        assert!(swa.position_dependent());
        assert_eq!(d.state[0].dtype, DType::I32);
        assert_eq!(d.state[0].numel(), 1); // scalar: empty shape, one element
        assert_eq!(d.state[1].shape, vec![2, 3, 64]);

        // Zero state: scalar i32 pos plus zeroed f32 leaves.
        let z = d.zero_state();
        assert_eq!(z.len(), 3);
        assert_eq!(z[0].as_i32().unwrap(), &[0]);
        assert_eq!(z[1].shape, vec![2, 3, 64]);
        assert!(z[2].as_f32().unwrap().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn manifest_decode_kv_cap_parses() {
        // Full-attention layouts record the cache capacity; null means no
        // full-attn lane (rolling SWA / pure SSM).
        let with_cap = MANIFEST.replacen(
            "\"name\": \"t\",",
            r#""name": "t",
            "decode": {
              "batch": 2, "prefill_lens": [16], "kv_cap": 1024,
              "state": [
                {"name": "pos", "shape": [], "dtype": "int32"},
                {"name": "blocks.0.k_cache", "shape": [2, 1024, 32], "dtype": "float32"},
                {"name": "blocks.0.v_cache", "shape": [2, 1024, 32], "dtype": "float32"}
              ]
            },"#,
            1,
        );
        let d = Manifest::parse(&with_cap).unwrap().decode.unwrap();
        assert_eq!(d.kv_cap, Some(1024));
        // Full-attn caches read `pos` (RoPE + validity mask): gang admission.
        assert!(d.position_dependent());
        let with_null = with_cap.replacen("\"kv_cap\": 1024,", "\"kv_cap\": null,", 1);
        assert_eq!(Manifest::parse(&with_null).unwrap().decode.unwrap().kv_cap, None);
    }
}
