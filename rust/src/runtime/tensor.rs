//! Host tensor: a thin shape+dtype wrapper over flat data, converting to and
//! from `xla::Literal`. This is the coordinator's lingua franca for batches,
//! parameters (checkpointing) and metrics.

use crate::substrate::json::Json;
use anyhow::{anyhow, bail, Result};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn from_str(s: &str) -> Result<DType> {
        match s {
            "float32" | "f32" => Ok(DType::F32),
            "int32" | "i32" => Ok(DType::I32),
            other => bail!("unsupported dtype {other:?}"),
        }
    }
    pub fn name(&self) -> &'static str {
        match self {
            DType::F32 => "float32",
            DType::I32 => "int32",
        }
    }
}

/// Flat host tensor (row-major).
#[derive(Debug, Clone)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: TensorData,
}

#[derive(Debug, Clone)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Tensor {
    pub fn f32(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape: shape.to_vec(), data: TensorData::F32(data) }
    }

    pub fn i32(shape: &[usize], data: Vec<i32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape: shape.to_vec(), data: TensorData::I32(data) }
    }

    pub fn scalar_f32(v: f32) -> Tensor {
        Tensor::f32(&[], vec![v])
    }

    pub fn scalar_i32(v: i32) -> Tensor {
        Tensor::i32(&[], vec![v])
    }

    pub fn zeros(shape: &[usize], dtype: DType) -> Tensor {
        let n: usize = shape.iter().product();
        match dtype {
            DType::F32 => Tensor::f32(shape, vec![0.0; n]),
            DType::I32 => Tensor::i32(shape, vec![0; n]),
        }
    }

    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> DType {
        match &self.data {
            TensorData::F32(_) => DType::F32,
            TensorData::I32(_) => DType::I32,
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            _ => Err(anyhow!("tensor is not f32")),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            _ => Err(anyhow!("tensor is not i32")),
        }
    }

    /// Scalar extraction (shape [] or [1]).
    pub fn item_f32(&self) -> Result<f32> {
        let v = self.as_f32()?;
        if v.len() != 1 {
            bail!("item() on tensor of {} elements", v.len());
        }
        Ok(v[0])
    }

    // ---- Literal conversion ------------------------------------------------
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        let lit = match &self.data {
            TensorData::F32(v) => xla::Literal::vec1(v),
            TensorData::I32(v) => xla::Literal::vec1(v),
        };
        Ok(lit.reshape(&dims)?)
    }

    pub fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(Tensor::f32(&dims, lit.to_vec::<f32>()?)),
            xla::ElementType::S32 => Ok(Tensor::i32(&dims, lit.to_vec::<i32>()?)),
            other => bail!("unsupported literal element type {other:?}"),
        }
    }

    // ---- JSON (checkpoint format) ------------------------------------------
    pub fn to_json(&self) -> Json {
        let data = match &self.data {
            TensorData::F32(v) => Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect()),
            TensorData::I32(v) => Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect()),
        };
        Json::obj(vec![
            ("shape", Json::arr_usize(&self.shape)),
            ("dtype", Json::str(self.dtype().name())),
            ("data", data),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Tensor> {
        let shape: Vec<usize> = j
            .get("shape")?
            .as_arr()?
            .iter()
            .map(|v| v.as_usize())
            .collect::<Result<_, _>>()?;
        let dtype = DType::from_str(j.get("dtype")?.as_str()?)?;
        let raw = j.get("data")?.as_arr()?;
        Ok(match dtype {
            DType::F32 => Tensor::f32(
                &shape,
                raw.iter().map(|v| v.as_f64().map(|x| x as f32)).collect::<Result<_, _>>()?,
            ),
            DType::I32 => Tensor::i32(
                &shape,
                raw.iter().map(|v| v.as_f64().map(|x| x as i32)).collect::<Result<_, _>>()?,
            ),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_product_checked() {
        let t = Tensor::f32(&[2, 3], vec![0.0; 6]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.dtype(), DType::F32);
    }

    #[test]
    #[should_panic]
    fn bad_shape_panics() {
        Tensor::f32(&[2, 3], vec![0.0; 5]);
    }

    #[test]
    fn json_roundtrip() {
        let t = Tensor::f32(&[2, 2], vec![1.0, -2.5, 3.0, 0.0]);
        let j = t.to_json();
        let t2 = Tensor::from_json(&j).unwrap();
        assert_eq!(t2.shape, t.shape);
        assert_eq!(t2.as_f32().unwrap(), t.as_f32().unwrap());
    }

    #[test]
    fn json_roundtrip_i32() {
        let t = Tensor::i32(&[3], vec![1, -2, 3]);
        let t2 = Tensor::from_json(&t.to_json()).unwrap();
        assert_eq!(t2.as_i32().unwrap(), t.as_i32().unwrap());
    }

    #[test]
    fn literal_roundtrip() {
        let t = Tensor::f32(&[2, 3], (0..6).map(|i| i as f32).collect());
        let lit = t.to_literal().unwrap();
        let t2 = Tensor::from_literal(&lit).unwrap();
        assert_eq!(t2.shape, vec![2, 3]);
        assert_eq!(t2.as_f32().unwrap(), t.as_f32().unwrap());
    }

    #[test]
    fn literal_roundtrip_scalar_i32() {
        let t = Tensor::scalar_i32(42);
        let lit = t.to_literal().unwrap();
        let t2 = Tensor::from_literal(&lit).unwrap();
        assert_eq!(t2.as_i32().unwrap(), &[42]);
        assert!(t2.shape.is_empty());
    }
}
