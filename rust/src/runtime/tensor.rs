//! Host tensor: a thin shape+dtype wrapper over flat data, converting to and
//! from `xla::Literal`. This is the coordinator's lingua franca for batches,
//! parameters (checkpointing) and metrics.

use std::io::Write;

use crate::substrate::json::Json;
use anyhow::{anyhow, bail, Result};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn from_str(s: &str) -> Result<DType> {
        match s {
            "float32" | "f32" => Ok(DType::F32),
            "int32" | "i32" => Ok(DType::I32),
            other => bail!("unsupported dtype {other:?}"),
        }
    }
    pub fn name(&self) -> &'static str {
        match self {
            DType::F32 => "float32",
            DType::I32 => "int32",
        }
    }
}

/// Flat host tensor (row-major).
#[derive(Debug, Clone)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: TensorData,
}

#[derive(Debug, Clone)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Tensor {
    pub fn f32(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape: shape.to_vec(), data: TensorData::F32(data) }
    }

    pub fn i32(shape: &[usize], data: Vec<i32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape: shape.to_vec(), data: TensorData::I32(data) }
    }

    pub fn scalar_f32(v: f32) -> Tensor {
        Tensor::f32(&[], vec![v])
    }

    pub fn scalar_i32(v: i32) -> Tensor {
        Tensor::i32(&[], vec![v])
    }

    pub fn zeros(shape: &[usize], dtype: DType) -> Tensor {
        let n: usize = shape.iter().product();
        match dtype {
            DType::F32 => Tensor::f32(shape, vec![0.0; n]),
            DType::I32 => Tensor::i32(shape, vec![0; n]),
        }
    }

    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> DType {
        match &self.data {
            TensorData::F32(_) => DType::F32,
            TensorData::I32(_) => DType::I32,
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            _ => Err(anyhow!("tensor is not f32")),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            _ => Err(anyhow!("tensor is not i32")),
        }
    }

    /// Scalar extraction (shape [] or [1]).
    pub fn item_f32(&self) -> Result<f32> {
        let v = self.as_f32()?;
        if v.len() != 1 {
            bail!("item() on tensor of {} elements", v.len());
        }
        Ok(v[0])
    }

    /// Payload size in bytes (both dtypes are 4-byte).
    pub fn byte_len(&self) -> usize {
        4 * self.len()
    }

    /// Elementwise `self += other` for f32 tensors of identical shape — the
    /// dp gradient reducer's inner loop. Plain left-to-right IEEE adds, so
    /// the caller fully controls the summation order (and with it, bitwise
    /// reproducibility of the reduced gradient).
    pub fn accumulate(&mut self, other: &Tensor) -> Result<()> {
        if self.shape != other.shape {
            bail!(
                "accumulate: shape {:?} != {:?}",
                self.shape,
                other.shape
            );
        }
        match (&mut self.data, &other.data) {
            (TensorData::F32(a), TensorData::F32(b)) => {
                for (x, y) in a.iter_mut().zip(b.iter()) {
                    *x += y;
                }
                Ok(())
            }
            _ => Err(anyhow!("accumulate: both tensors must be f32")),
        }
    }

    // ---- Bulk little-endian transport --------------------------------------
    // Checkpoints and any future wire format move multi-MB parameter state;
    // these helpers work at slice granularity (one memcpy on little-endian
    // hosts) instead of pushing 4 bytes per element through an iterator.

    /// Stream the payload as little-endian bytes into `w`.
    pub fn write_le_bytes<W: Write>(&self, w: &mut W) -> Result<()> {
        match &self.data {
            TensorData::F32(v) => write_slice_le(w, v.as_slice(), |x| x.to_le_bytes()),
            TensorData::I32(v) => write_slice_le(w, v.as_slice(), |x| x.to_le_bytes()),
        }
    }

    /// Rebuild a tensor from the little-endian payload written by
    /// `write_le_bytes`. `bytes` must be exactly `4 * shape.product()` long.
    pub fn from_le_bytes(shape: &[usize], dtype: DType, bytes: &[u8]) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if bytes.len() != 4 * n {
            bail!(
                "payload is {} bytes, shape {shape:?} ({dtype:?}) needs {}",
                bytes.len(),
                4 * n
            );
        }
        Ok(match dtype {
            DType::F32 => Tensor::f32(shape, read_slice_le(bytes, f32::from_le_bytes)),
            DType::I32 => Tensor::i32(shape, read_slice_le(bytes, i32::from_le_bytes)),
        })
    }

    // ---- Literal conversion ------------------------------------------------
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        let lit = match &self.data {
            TensorData::F32(v) => xla::Literal::vec1(v),
            TensorData::I32(v) => xla::Literal::vec1(v),
        };
        Ok(lit.reshape(&dims)?)
    }

    pub fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(Tensor::f32(&dims, lit.to_vec::<f32>()?)),
            xla::ElementType::S32 => Ok(Tensor::i32(&dims, lit.to_vec::<i32>()?)),
            other => bail!("unsupported literal element type {other:?}"),
        }
    }

    // ---- JSON (checkpoint format) ------------------------------------------
    pub fn to_json(&self) -> Json {
        let data = match &self.data {
            TensorData::F32(v) => Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect()),
            TensorData::I32(v) => Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect()),
        };
        Json::obj(vec![
            ("shape", Json::arr_usize(&self.shape)),
            ("dtype", Json::str(self.dtype().name())),
            ("data", data),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Tensor> {
        let shape: Vec<usize> = j
            .get("shape")?
            .as_arr()?
            .iter()
            .map(|v| v.as_usize())
            .collect::<Result<_, _>>()?;
        let dtype = DType::from_str(j.get("dtype")?.as_str()?)?;
        let raw = j.get("data")?.as_arr()?;
        Ok(match dtype {
            DType::F32 => Tensor::f32(
                &shape,
                raw.iter().map(|v| v.as_f64().map(|x| x as f32)).collect::<Result<_, _>>()?,
            ),
            DType::I32 => Tensor::i32(
                &shape,
                raw.iter().map(|v| v.as_f64().map(|x| x as i32)).collect::<Result<_, _>>()?,
            ),
        })
    }
}

/// Bulk little-endian write of a `[f32]`/`[i32]` slice. On little-endian
/// targets (every platform this repo runs on) the in-memory representation is
/// already the wire format, so this is a single `write_all` over the
/// reinterpreted slice; the per-element path only exists for big-endian hosts.
fn write_slice_le<W: Write, T: Copy, const N: usize>(
    w: &mut W,
    v: &[T],
    to_le: fn(T) -> [u8; N],
) -> Result<()> {
    if cfg!(target_endian = "little") {
        // SAFETY: T is a 4-byte plain-old-data scalar (f32/i32) with no
        // padding; viewing its memory as bytes is always valid.
        let bytes = unsafe {
            std::slice::from_raw_parts(v.as_ptr() as *const u8, std::mem::size_of_val(v))
        };
        w.write_all(bytes)?;
    } else {
        for &x in v {
            w.write_all(&to_le(x))?;
        }
    }
    Ok(())
}

/// Bulk little-endian read into a freshly allocated scalar vec (inverse of
/// `write_slice_le`). Caller has already validated `bytes.len() % 4 == 0`.
fn read_slice_le<T: Copy + Default>(bytes: &[u8], from_le: fn([u8; 4]) -> T) -> Vec<T> {
    let n = bytes.len() / 4;
    if cfg!(target_endian = "little") {
        let mut out = vec![T::default(); n];
        // SAFETY: out is n 4-byte POD scalars = bytes.len() bytes of valid,
        // writable memory; every bit pattern is a valid f32/i32.
        unsafe {
            std::slice::from_raw_parts_mut(out.as_mut_ptr() as *mut u8, bytes.len())
                .copy_from_slice(bytes);
        }
        out
    } else {
        bytes
            .chunks_exact(4)
            .map(|c| from_le([c[0], c[1], c[2], c[3]]))
            .collect()
    }
}

/// Encode a borrowed i32 slice straight to a device literal, skipping the
/// intermediate `Tensor` allocation (hot path: microbatch dispatch).
pub fn literal_from_i32(shape: &[usize], data: &[i32]) -> Result<xla::Literal> {
    debug_assert_eq!(shape.iter().product::<usize>(), data.len());
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

/// `xla::Literal` owns plain host memory and carries no thread-affine state
/// (it is independent of the PJRT client — construction via `Literal::vec1`
/// never touches a device), but the FFI wrapper does not declare `Send`. The
/// prefetch pipeline encodes literals on a background thread and hands them
/// to the step loop; this newtype carries them across. This is the ONLY
/// `unsafe impl Send` in the crate: the Arc-based runtime refactor removed
/// every other cross-thread need, but literal encode-off-thread is the whole
/// point of the pipeline's second stage, so the shim stays.
pub struct SendLiteral(pub xla::Literal);

// SAFETY: a Literal is an owned host-side buffer + shape metadata; moving it
// between threads is moving a heap allocation. No interior shared state.
// Exercised by `send_literal_crosses_threads` below, which encodes on a
// background thread, moves the literal across a channel, and decodes on the
// receiving thread — the exact transport the prefetch pipeline performs.
unsafe impl Send for SendLiteral {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_product_checked() {
        let t = Tensor::f32(&[2, 3], vec![0.0; 6]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.dtype(), DType::F32);
    }

    #[test]
    #[should_panic]
    fn bad_shape_panics() {
        Tensor::f32(&[2, 3], vec![0.0; 5]);
    }

    #[test]
    fn json_roundtrip() {
        let t = Tensor::f32(&[2, 2], vec![1.0, -2.5, 3.0, 0.0]);
        let j = t.to_json();
        let t2 = Tensor::from_json(&j).unwrap();
        assert_eq!(t2.shape, t.shape);
        assert_eq!(t2.as_f32().unwrap(), t.as_f32().unwrap());
    }

    #[test]
    fn json_roundtrip_i32() {
        let t = Tensor::i32(&[3], vec![1, -2, 3]);
        let t2 = Tensor::from_json(&t.to_json()).unwrap();
        assert_eq!(t2.as_i32().unwrap(), t.as_i32().unwrap());
    }

    #[test]
    fn literal_roundtrip() {
        let t = Tensor::f32(&[2, 3], (0..6).map(|i| i as f32).collect());
        let lit = t.to_literal().unwrap();
        let t2 = Tensor::from_literal(&lit).unwrap();
        assert_eq!(t2.shape, vec![2, 3]);
        assert_eq!(t2.as_f32().unwrap(), t.as_f32().unwrap());
    }

    #[test]
    fn le_bytes_roundtrip_f32() {
        let t = Tensor::f32(&[2, 3], vec![1.5, -2.25, 0.0, f32::MIN, f32::MAX, 3e-9]);
        let mut buf = Vec::new();
        t.write_le_bytes(&mut buf).unwrap();
        assert_eq!(buf.len(), t.byte_len());
        // Wire format is exactly per-element to_le_bytes.
        assert_eq!(&buf[..4], &1.5f32.to_le_bytes());
        let back = Tensor::from_le_bytes(&t.shape, DType::F32, &buf).unwrap();
        assert_eq!(back.as_f32().unwrap(), t.as_f32().unwrap());
    }

    #[test]
    fn le_bytes_roundtrip_i32() {
        let t = Tensor::i32(&[4], vec![i32::MIN, -1, 0, i32::MAX]);
        let mut buf = Vec::new();
        t.write_le_bytes(&mut buf).unwrap();
        let back = Tensor::from_le_bytes(&[4], DType::I32, &buf).unwrap();
        assert_eq!(back.as_i32().unwrap(), t.as_i32().unwrap());
    }

    #[test]
    fn le_bytes_rejects_wrong_length() {
        assert!(Tensor::from_le_bytes(&[3], DType::F32, &[0u8; 8]).is_err());
        assert!(Tensor::from_le_bytes(&[0], DType::I32, &[]).is_ok());
    }

    #[test]
    fn le_bytes_reinterpretation_is_alignment_safe() {
        // Miri target for the two `unsafe` blocks above: the little-endian
        // fast path views scalar memory as bytes (write) and writes bytes
        // into freshly allocated scalar memory (read). Drive the read from
        // a source window at an odd offset inside a larger buffer, and both
        // directions with a zero-length payload, so `cargo miri test`
        // checks the raw-pointer arithmetic at the awkward edges.
        let t = Tensor::f32(&[3], vec![1.0, -2.0, 3.5]);
        let mut buf = vec![0xAAu8; 1]; // 1-byte prefix: payload starts unaligned
        t.write_le_bytes(&mut buf).unwrap();
        assert_eq!(buf.len(), 1 + t.byte_len());
        let back = Tensor::from_le_bytes(&[3], DType::F32, &buf[1..]).unwrap();
        assert_eq!(back.as_f32().unwrap(), t.as_f32().unwrap());

        let empty = Tensor::i32(&[0], vec![]);
        let mut ebuf = Vec::new();
        empty.write_le_bytes(&mut ebuf).unwrap();
        assert!(ebuf.is_empty());
        let eback = Tensor::from_le_bytes(&[0], DType::I32, &ebuf).unwrap();
        assert_eq!(eback.len(), 0);
    }

    #[test]
    fn literal_from_slice_matches_tensor_path() {
        let data = vec![7i32, 8, 9, 10, 11, 12];
        let lit = literal_from_i32(&[2, 3], &data).unwrap();
        let t = Tensor::from_literal(&lit).unwrap();
        assert_eq!(t.shape, vec![2, 3]);
        assert_eq!(t.as_i32().unwrap(), &data[..]);
    }

    #[test]
    fn send_literal_crosses_threads() {
        // The SAFETY contract of `unsafe impl Send for SendLiteral`: a
        // literal encoded on one thread decodes bit-identically after moving
        // to another (the prefetch pipeline's stage-2 -> step-loop handoff).
        let (tx, rx) = std::sync::mpsc::channel();
        std::thread::spawn(move || {
            let t = Tensor::f32(&[2, 3], vec![1.5, -2.25, 0.0, f32::MIN, f32::MAX, 3e-9]);
            tx.send(SendLiteral(t.to_literal().unwrap())).unwrap();
        })
        .join()
        .unwrap();
        let lit = rx.recv().unwrap();
        let back = Tensor::from_literal(&lit.0).unwrap();
        assert_eq!(back.shape, vec![2, 3]);
        assert_eq!(
            back.as_f32().unwrap(),
            &[1.5, -2.25, 0.0, f32::MIN, f32::MAX, 3e-9]
        );
    }

    #[test]
    fn literal_roundtrip_scalar_i32() {
        let t = Tensor::scalar_i32(42);
        let lit = t.to_literal().unwrap();
        let t2 = Tensor::from_literal(&lit).unwrap();
        assert_eq!(t2.as_i32().unwrap(), &[42]);
        assert!(t2.shape.is_empty());
    }
}
