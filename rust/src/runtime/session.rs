//! Training session: owns the model/optimizer state (as XLA literals) and
//! drives the step/grad/apply/eval programs of one `Bundle` — plus the
//! stateful prefill/decode_step generation entry points, whose carried
//! recurrent state (`DecodeState`) never round-trips through host tensors
//! between tokens.
//!
//! This is the boundary between the rust coordinator (batches, schedules,
//! telemetry) and the AOT-compiled jax computation. State stays in
//! `xla::Literal`s between steps; only the loss scalar is decoded per step —
//! router-load telemetry is decoded opt-in (sampled by the trainer at its
//! logging cadence), and the gradient-accumulation zero buffer is uploaded
//! once at `init`/`restore` and reused for the life of the session
//! (§Perf L3 log in EXPERIMENTS.md).
//!
//! Sessions are lifetime-free: a `Session` owns an `Arc<Bundle>` rather than
//! borrowing it, so scheduler workers can construct sessions wherever their
//! bundle lives and return them up the stack (`Trainer::run_session`) without
//! threading borrow lifetimes through every layer.

use std::cell::Cell;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::runtime::artifact::Bundle;
use crate::runtime::tensor::{Tensor, TensorData};

/// Loss + telemetry decoded from one training step.
#[derive(Debug, Clone)]
pub struct StepOut {
    pub loss: f64,
    /// (num_routers x num_experts) dispatch fractions, row-major. `None`
    /// when the caller skipped the decode (telemetry is sampled, not free:
    /// it forces a device->host transfer every step), or when the grad
    /// artifact predates the router-load output (legacy accum path).
    pub router_load: Option<Vec<f32>>,
}

/// One microbatch's RAW gradient decoded to host — the unit of the `--dp`
/// host-side gradient exchange. Unlike the accum path (which chains the
/// device-side accumulator), `grad_to_host` seeds every call from the
/// persistent zero literals, so `grads` is exactly this microbatch's
/// gradient; the dp reducer owns the summation order (flat, rank-major —
/// the fixed association that makes the sum world-size invariant).
#[derive(Debug, Clone)]
pub struct MicroGrad {
    pub grads: Vec<Tensor>,
    pub loss: f64,
    /// Router telemetry for this microbatch, when decoded (see `StepOut`).
    pub router_load: Option<Vec<f32>>,
}

/// The carried recurrent state of an in-flight generation: one literal per
/// leaf of the manifest's decode-state spec (leaf 0 is the i32 `pos`
/// scalar). The state stays in `xla::Literal`s between steps — it is fed
/// straight back into the next `decode_step` call without a host decode;
/// only the (batch, vocab) logits are decoded per token for sampling.
pub struct DecodeState {
    lits: Vec<xla::Literal>,
    /// Tokens consumed so far (host-side mirror of the `pos` leaf, kept for
    /// reporting without a device->host transfer). For full-attention
    /// layouts this is also the KV-cache slot the NEXT decode_step will
    /// write, so the serve engine compares it against `decode.kv_cap`
    /// before stepping — the device-side scatter clamps out-of-range
    /// indices rather than failing.
    pub pos: u64,
}

pub struct Session {
    pub bundle: Arc<Bundle>,
    params: Vec<xla::Literal>,
    m: Vec<xla::Literal>,
    v: Vec<xla::Literal>,
    /// Zeroed per-leaf gradient accumulator, uploaded once; `train_step_accum`
    /// seeds every optimizer step from these literals instead of re-allocating
    /// and re-uploading a full model's worth of zeros per step.
    grad_zero: Vec<xla::Literal>,
    /// Every session-side `Tensor -> Literal` conversion goes through
    /// `upload()` and bumps this. The perf regression test asserts the exact
    /// per-step delta (batch encodes + scalars), which catches any
    /// reintroduced per-step gradient-buffer upload — that would add
    /// `num_leaves` to the count.
    host_uploads: Cell<u64>,
    step_count: u64,
}

impl Session {
    /// Initialize model params on device from `seed`; optimizer state zeroed.
    pub fn init(bundle: Arc<Bundle>, seed: i32) -> Result<Session> {
        let p = bundle.init()?;
        let seed_lit = Tensor::scalar_i32(seed).to_literal()?;
        let params = p.run(&[&seed_lit]).context("init artifact")?;
        let n = bundle.manifest.num_leaves();
        if params.len() != n {
            bail!("init returned {} leaves, manifest says {n}", params.len());
        }
        // Build the zero tensors once, upload three times (m, v, grad_zero) —
        // avoids the per-leaf literal->host->literal round-trip of a naive
        // clone (§Perf L3 log in EXPERIMENTS.md).
        let zero_tensors = bundle.manifest.zeros_like_params();
        let m = zero_tensors.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let v = zero_tensors.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let grad_zero =
            zero_tensors.iter().map(|t| t.to_literal()).collect::<Result<Vec<_>>>()?;
        Ok(Session {
            bundle,
            params,
            m,
            v,
            host_uploads: Cell::new(1 + 3 * grad_zero.len() as u64),
            grad_zero,
            step_count: 0,
        })
    }

    /// Restore from checkpointed tensors (params, m, v, step_count).
    pub fn restore(
        bundle: Arc<Bundle>,
        params: &[Tensor],
        m: &[Tensor],
        v: &[Tensor],
        step_count: u64,
    ) -> Result<Session> {
        let n = bundle.manifest.num_leaves();
        if params.len() != n || m.len() != n || v.len() != n {
            bail!("checkpoint leaf count mismatch");
        }
        let conv = |ts: &[Tensor]| -> Result<Vec<xla::Literal>> {
            ts.iter().map(|t| t.to_literal()).collect()
        };
        let grad_zero = bundle
            .manifest
            .zeros_like_params()
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<Vec<_>>>()?;
        Ok(Session {
            bundle,
            params: conv(params)?,
            m: conv(m)?,
            v: conv(v)?,
            host_uploads: Cell::new(4 * grad_zero.len() as u64),
            grad_zero,
            step_count,
        })
    }

    pub fn step_count(&self) -> u64 {
        self.step_count
    }

    /// Total host->device uploads (Tensor -> Literal conversions) this
    /// session has performed, constructors included. Tests pin the per-step
    /// delta of this counter to catch reintroduced hot-path uploads.
    pub fn host_uploads(&self) -> u64 {
        self.host_uploads.get()
    }

    /// Sole session-side upload point: converts and counts.
    fn upload(&self, t: &Tensor) -> Result<xla::Literal> {
        self.host_uploads.set(self.host_uploads.get() + 1);
        t.to_literal()
    }

    /// Fused train step on a full (B, T) host batch: encodes to literals and
    /// delegates. Decodes router telemetry unconditionally (the historical
    /// behavior; the pipelined trainer calls `train_step_device` and samples).
    pub fn train_step(&mut self, lr: f32, tokens: &Tensor, targets: &Tensor) -> Result<StepOut> {
        let man = &self.bundle.manifest;
        expect_shape(tokens, &[man.batch_size, man.seq_len], "tokens")?;
        expect_shape(targets, &[man.batch_size, man.seq_len], "targets")?;
        let tok = self.upload(tokens)?;
        let tgt = self.upload(targets)?;
        self.train_step_device(lr, &tok, &tgt, true)
    }

    /// Fused train step on pre-encoded (B, T) literals — the pipelined hot
    /// path. The caller owns shape discipline (the loader/pipeline already
    /// produce exact (B, T) windows); `decode_router_load` gates the
    /// device->host telemetry transfer.
    pub fn train_step_device(
        &mut self,
        lr: f32,
        tokens: &xla::Literal,
        targets: &xla::Literal,
        decode_router_load: bool,
    ) -> Result<StepOut> {
        let prog = self.bundle.step()?;
        self.step_count += 1;
        let stepnum = self.upload(&Tensor::scalar_f32(self.step_count as f32))?;
        let lr_lit = self.upload(&Tensor::scalar_f32(lr))?;

        let mut inputs: Vec<&xla::Literal> =
            Vec::with_capacity(3 * self.params.len() + 4);
        inputs.extend(self.params.iter());
        inputs.extend(self.m.iter());
        inputs.extend(self.v.iter());
        inputs.push(&stepnum);
        inputs.push(&lr_lit);
        inputs.push(tokens);
        inputs.push(targets);

        let mut outs = prog.run(&inputs)?;
        let n = self.params.len();
        if outs.len() != 3 * n + 2 {
            bail!("step returned {} outputs, expected {}", outs.len(), 3 * n + 2);
        }
        let load_lit = outs.pop().unwrap();
        let loss_lit = outs.pop().unwrap();
        self.v = outs.split_off(2 * n);
        self.m = outs.split_off(n);
        self.params = outs;

        let router_load = if decode_router_load {
            Some(Tensor::from_literal(&load_lit)?.as_f32()?.to_vec())
        } else {
            None
        };
        Ok(StepOut {
            loss: Tensor::from_literal(&loss_lit)?.item_f32()? as f64,
            router_load,
        })
    }

    /// Microbatch grad-accumulation path on host tensors: encodes each
    /// microbatch and delegates to the device path. Decodes router telemetry
    /// when the artifact provides it (the historical `train_step` behavior;
    /// the pipelined trainer calls `train_step_accum_device` and samples).
    pub fn train_step_accum(
        &mut self,
        lr: f32,
        microbatches: &[(Tensor, Tensor)],
    ) -> Result<StepOut> {
        let man = &self.bundle.manifest;
        let mut device = Vec::with_capacity(microbatches.len());
        for (tokens, targets) in microbatches {
            expect_shape(tokens, &[man.micro_batch, man.seq_len], "micro tokens")?;
            device.push((self.upload(tokens)?, self.upload(targets)?));
        }
        let refs: Vec<(&xla::Literal, &xla::Literal)> =
            device.iter().map(|(t, g)| (t, g)).collect();
        self.train_step_accum_device(lr, &refs, true)
    }

    /// Microbatch grad-accumulation on pre-encoded literals: accumulate over
    /// `micro` batches of (micro_batch, T), then apply once. The accumulator
    /// is seeded from the session's persistent `grad_zero` literals — zero
    /// gradient-buffer allocations or uploads happen here.
    ///
    /// Returns the mean loss plus router telemetry sampled from the LAST
    /// microbatch (each microbatch routes independently; one sample per
    /// optimizer step is what the balance EMA consumes). `router_load` is
    /// `None` when `decode_router_load` is false or when the grad artifact
    /// predates the load output (legacy arity n+1 instead of n+2).
    pub fn train_step_accum_device(
        &mut self,
        lr: f32,
        microbatches: &[(&xla::Literal, &xla::Literal)],
        decode_router_load: bool,
    ) -> Result<StepOut> {
        if microbatches.is_empty() {
            bail!("no microbatches");
        }
        let grad = self.bundle.grad()?;
        let apply = self.bundle.apply()?;
        let n = self.params.len();

        // First microbatch reads the persistent zero literals; afterwards the
        // accumulator is whatever the grad program last returned.
        let mut gacc: Option<Vec<xla::Literal>> = None;
        let mut loss_sum = 0.0f64;
        let mut load_lit: Option<xla::Literal> = None;
        for &(tok, tgt) in microbatches {
            let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(2 * n + 2);
            inputs.extend(self.params.iter());
            match &gacc {
                Some(g) => inputs.extend(g.iter()),
                None => inputs.extend(self.grad_zero.iter()),
            }
            inputs.push(tok);
            inputs.push(tgt);
            let mut outs = grad.run(&inputs)?;
            // Newer grad artifacts append the router load as a final output
            // (n+2); legacy bundles emit n+1 and simply report no telemetry.
            if outs.len() == n + 2 {
                load_lit = Some(outs.pop().unwrap());
            } else if outs.len() != n + 1 {
                bail!(
                    "grad returned {} outputs, expected {} or {}",
                    outs.len(),
                    n + 1,
                    n + 2
                );
            }
            let loss_lit = outs.pop().unwrap();
            gacc = Some(outs);
            loss_sum += Tensor::from_literal(&loss_lit)?.item_f32()? as f64;
        }
        let gacc = gacc.expect("at least one microbatch");

        self.step_count += 1;
        let stepnum = self.upload(&Tensor::scalar_f32(self.step_count as f32))?;
        let lr_lit = self.upload(&Tensor::scalar_f32(lr))?;
        let nmicro = self.upload(&Tensor::scalar_f32(microbatches.len() as f32))?;
        let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(4 * n + 3);
        inputs.extend(self.params.iter());
        inputs.extend(self.m.iter());
        inputs.extend(self.v.iter());
        inputs.extend(gacc.iter());
        inputs.push(&stepnum);
        inputs.push(&lr_lit);
        inputs.push(&nmicro);
        let mut outs = apply.run(&inputs)?;
        if outs.len() != 3 * n {
            bail!("apply returned {} outputs, expected {}", outs.len(), 3 * n);
        }
        self.v = outs.split_off(2 * n);
        self.m = outs.split_off(n);
        self.params = outs;
        let router_load = match (decode_router_load, load_lit) {
            (true, Some(l)) => Some(Tensor::from_literal(&l)?.as_f32()?.to_vec()),
            _ => None,
        };
        Ok(StepOut { loss: loss_sum / microbatches.len() as f64, router_load })
    }

    /// Run the grad program on one pre-encoded microbatch and decode the RAW
    /// gradient — seeded from the persistent `grad_zero` literals, never a
    /// carried accumulator — plus the loss to host. Takes `&self`: params are
    /// untouched. This is the per-replica half of a data-parallel step; the
    /// matching update half is `apply_reduced`.
    pub fn grad_to_host(
        &self,
        tokens: &xla::Literal,
        targets: &xla::Literal,
        decode_router_load: bool,
    ) -> Result<MicroGrad> {
        let grad = self.bundle.grad()?;
        let n = self.params.len();
        let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(2 * n + 2);
        inputs.extend(self.params.iter());
        inputs.extend(self.grad_zero.iter());
        inputs.push(tokens);
        inputs.push(targets);
        let mut outs = grad.run(&inputs)?;
        // Same arity convention as the accum path: newer grad artifacts
        // append the router load (n+2), legacy bundles emit n+1.
        let mut load_lit: Option<xla::Literal> = None;
        if outs.len() == n + 2 {
            load_lit = Some(outs.pop().unwrap());
        } else if outs.len() != n + 1 {
            bail!(
                "grad returned {} outputs, expected {} or {}",
                outs.len(),
                n + 1,
                n + 2
            );
        }
        let loss_lit = outs.pop().unwrap();
        let grads = outs.iter().map(Tensor::from_literal).collect::<Result<Vec<_>>>()?;
        let router_load = match (decode_router_load, load_lit) {
            (true, Some(l)) => Some(Tensor::from_literal(&l)?.as_f32()?.to_vec()),
            _ => None,
        };
        Ok(MicroGrad {
            grads,
            loss: Tensor::from_literal(&loss_lit)?.item_f32()? as f64,
            router_load,
        })
    }

    /// Apply one optimizer update from an externally reduced gradient sum —
    /// the `--dp` update half. Uploads the summed gradients and runs the
    /// apply program exactly as the accum path does with its device-side
    /// accumulator; `num_micro` is the GLOBAL microbatch count the sum spans,
    /// so the update matches a single-replica accum step over the same
    /// global batch.
    pub fn apply_reduced(&mut self, lr: f32, grads: &[Tensor], num_micro: usize) -> Result<()> {
        let n = self.params.len();
        if grads.len() != n {
            bail!("reduced gradient has {} leaves, params have {n}", grads.len());
        }
        if num_micro == 0 {
            bail!("reduced gradient spans zero microbatches");
        }
        let apply = self.bundle.apply()?;
        let gacc = grads.iter().map(|g| self.upload(g)).collect::<Result<Vec<_>>>()?;
        self.step_count += 1;
        let stepnum = self.upload(&Tensor::scalar_f32(self.step_count as f32))?;
        let lr_lit = self.upload(&Tensor::scalar_f32(lr))?;
        let nmicro = self.upload(&Tensor::scalar_f32(num_micro as f32))?;
        let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(4 * n + 3);
        inputs.extend(self.params.iter());
        inputs.extend(self.m.iter());
        inputs.extend(self.v.iter());
        inputs.extend(gacc.iter());
        inputs.push(&stepnum);
        inputs.push(&lr_lit);
        inputs.push(&nmicro);
        let mut outs = apply.run(&inputs)?;
        if outs.len() != 3 * n {
            bail!("apply returned {} outputs, expected {}", outs.len(), 3 * n);
        }
        self.v = outs.split_off(2 * n);
        self.m = outs.split_off(n);
        self.params = outs;
        Ok(())
    }

    /// Evaluate summed NLL + token count on one (1, L) sequence pair.
    pub fn eval(&self, len: usize, tokens: &Tensor, targets: &Tensor) -> Result<(f64, f64)> {
        expect_shape(tokens, &[1, len], "eval tokens")?;
        let prog = self.bundle.eval(len)?;
        let tok = self.upload(tokens)?;
        let tgt = self.upload(targets)?;
        let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(self.params.len() + 2);
        inputs.extend(self.params.iter());
        inputs.push(&tok);
        inputs.push(&tgt);
        let outs = prog.run(&inputs)?;
        if outs.len() != 2 {
            bail!("eval returned {} outputs, expected 2", outs.len());
        }
        Ok((
            Tensor::from_literal(&outs[0])?.item_f32()? as f64,
            Tensor::from_literal(&outs[1])?.item_f32()? as f64,
        ))
    }

    /// Final-position-only NLL (cloze probe primitive; see Bundle::eval_last).
    pub fn eval_last(&self, len: usize, tokens: &Tensor, targets: &Tensor) -> Result<(f64, f64)> {
        expect_shape(tokens, &[1, len], "eval_last tokens")?;
        let prog = self.bundle.eval_last(len)?;
        let tok = self.upload(tokens)?;
        let tgt = self.upload(targets)?;
        let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(self.params.len() + 2);
        inputs.extend(self.params.iter());
        inputs.push(&tok);
        inputs.push(&tgt);
        let outs = prog.run(&inputs)?;
        if outs.len() != 2 {
            bail!("eval_last returned {} outputs, expected 2", outs.len());
        }
        Ok((
            Tensor::from_literal(&outs[0])?.item_f32()? as f64,
            Tensor::from_literal(&outs[1])?.item_f32()? as f64,
        ))
    }

    // ---- Autoregressive decoding -------------------------------------------
    // Stateful generation entry points over the prefill_L{L}/decode_step
    // artifacts. The recurrent state is a `DecodeState` of literals that
    // shuttles between calls; `coordinator::generate` drives the sampling
    // loop on top of these.

    /// Start-of-sequence generation state (pos = 0, zeroed recurrences) —
    /// the seed for the decode-step prompt fallback when no prefill artifact
    /// matches the prompt length.
    pub fn init_decode_state(&self) -> Result<DecodeState> {
        let spec = self.bundle.decode_spec()?;
        let lits = spec
            .zero_state()
            .iter()
            .map(|t| self.upload(t))
            .collect::<Result<Vec<_>>>()?;
        Ok(DecodeState { lits, pos: 0 })
    }

    /// Consume a whole (decode_batch, L) prompt in one device call; returns
    /// the last-position logits as a host (batch, vocab) tensor plus the
    /// packed recurrent state. L must be one of the manifest's prefill
    /// lengths (`Bundle::prefill` enforces it).
    pub fn prefill(&self, tokens: &Tensor) -> Result<(Tensor, DecodeState)> {
        let spec = self.bundle.decode_spec()?;
        let len = match tokens.shape.as_slice() {
            [b, l] if *b == spec.batch => *l,
            other => bail!(
                "prefill tokens: shape {other:?} != expected [{}, L]",
                spec.batch
            ),
        };
        let prog = self.bundle.prefill(len)?;
        let tok = self.upload(tokens)?;
        let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(self.params.len() + 1);
        inputs.extend(self.params.iter());
        inputs.push(&tok);
        let outs = prog.run(&inputs)?;
        self.split_decode_outputs(outs, "prefill", len as u64)
    }

    /// One decode step: (decode_batch,) token ids + carried state -> logits
    /// for the next position. The state literals are replaced in place; no
    /// recurrent-state host roundtrip happens per token.
    pub fn decode_step(&self, tokens: &Tensor, state: &mut DecodeState) -> Result<Tensor> {
        let spec = self.bundle.decode_spec()?;
        expect_shape(tokens, &[spec.batch], "decode_step tokens")?;
        let prog = self.bundle.decode_step()?;
        let tok = self.upload(tokens)?;
        let mut inputs: Vec<&xla::Literal> =
            Vec::with_capacity(self.params.len() + 1 + state.lits.len());
        inputs.extend(self.params.iter());
        inputs.push(&tok);
        inputs.extend(state.lits.iter());
        let outs = prog.run(&inputs)?;
        let next_pos = state.pos + 1;
        let (logits, new_state) = self.split_decode_outputs(outs, "decode_step", next_pos)?;
        *state = new_state;
        Ok(logits)
    }

    // ---- Per-slot state lanes ---------------------------------------------
    // A batched DecodeState carries `decode_spec().batch` independent
    // sequences, one per leading-dim row of every non-pos leaf. These entry
    // points move ONE row between states (host roundtrip — used at request
    // swap-in/swap-out cadence by the serve engine, never per token). The
    // shared `pos` scalar (leaf 0) is deliberately untouched: layouts whose
    // blocks read it cannot mix rows at different positions in one batch
    // (`DecodeSpec::position_dependent`), and layouts that can mix rows
    // never read it.

    /// Extract row `row` of every recurrent state lane as host tensors of
    /// shape `[1, ...]` (leaf 0, the `pos` scalar, is skipped — it has no
    /// per-row lane).
    pub fn extract_state_row(&self, state: &DecodeState, row: usize) -> Result<Vec<Tensor>> {
        let spec = self.bundle.decode_spec()?;
        self.check_state_row(state, row, "extract_state_row")?;
        let mut out = Vec::with_capacity(state.lits.len().saturating_sub(1));
        for (leaf, lit) in state.lits.iter().enumerate().skip(1) {
            let t = Tensor::from_literal(lit)?;
            let row_elems = lane_elems(&t, spec.batch, leaf)?;
            let mut shape = t.shape.clone();
            shape[0] = 1;
            let lane = match &t.data {
                TensorData::F32(v) => {
                    Tensor::f32(&shape, v[row * row_elems..][..row_elems].to_vec())
                }
                TensorData::I32(v) => {
                    Tensor::i32(&shape, v[row * row_elems..][..row_elems].to_vec())
                }
            };
            out.push(lane);
        }
        Ok(out)
    }

    /// Overwrite row `dst_row` of every recurrent state lane in `dst` with
    /// row `src_row` of `src` — the serve engine's swap-in: a freshly
    /// prefilled sequence (row `src_row` of a scratch state) takes over one
    /// slot of the live batched state. Only the edited leaves re-upload;
    /// `pos` and every other row are untouched.
    pub fn inject_state_row(
        &self,
        dst: &mut DecodeState,
        dst_row: usize,
        src: &DecodeState,
        src_row: usize,
    ) -> Result<()> {
        let spec = self.bundle.decode_spec()?;
        self.check_state_row(dst, dst_row, "inject_state_row dst")?;
        self.check_state_row(src, src_row, "inject_state_row src")?;
        for leaf in 1..dst.lits.len() {
            let mut d = Tensor::from_literal(&dst.lits[leaf])?;
            let s = Tensor::from_literal(&src.lits[leaf])?;
            if d.shape != s.shape {
                bail!(
                    "inject_state_row: leaf {leaf} shape {:?} vs {:?}",
                    d.shape,
                    s.shape
                );
            }
            let row_elems = lane_elems(&d, spec.batch, leaf)?;
            match (&mut d.data, &s.data) {
                (TensorData::F32(dv), TensorData::F32(sv)) => {
                    dv[dst_row * row_elems..][..row_elems]
                        .copy_from_slice(&sv[src_row * row_elems..][..row_elems]);
                }
                (TensorData::I32(dv), TensorData::I32(sv)) => {
                    dv[dst_row * row_elems..][..row_elems]
                        .copy_from_slice(&sv[src_row * row_elems..][..row_elems]);
                }
                _ => bail!("inject_state_row: leaf {leaf} dtype mismatch"),
            }
            dst.lits[leaf] = self.upload(&d)?;
        }
        Ok(())
    }

    /// Shared validation for the per-slot lane entry points.
    fn check_state_row(&self, state: &DecodeState, row: usize, what: &str) -> Result<()> {
        let spec = self.bundle.decode_spec()?;
        if state.lits.len() != spec.state.len() {
            bail!(
                "{what}: state has {} leaves, spec says {}",
                state.lits.len(),
                spec.state.len()
            );
        }
        if row >= spec.batch {
            bail!("{what}: row {row} outside the decode batch of {}", spec.batch);
        }
        Ok(())
    }

    /// Decompose a decode-artifact output tuple: leaf 0 is the logits (the
    /// only per-token host decode), the rest is the carried state.
    fn split_decode_outputs(
        &self,
        mut outs: Vec<xla::Literal>,
        what: &str,
        pos: u64,
    ) -> Result<(Tensor, DecodeState)> {
        let n_state = self.bundle.decode_spec()?.state.len();
        if outs.len() != n_state + 1 {
            bail!("{what} returned {} outputs, expected {}", outs.len(), n_state + 1);
        }
        let lits = outs.split_off(1);
        let logits = Tensor::from_literal(&outs[0])?;
        Ok((logits, DecodeState { lits, pos }))
    }

    /// Copy current state to host tensors (checkpointing).
    pub fn export(&self) -> Result<(Vec<Tensor>, Vec<Tensor>, Vec<Tensor>)> {
        let conv = |ls: &[xla::Literal]| -> Result<Vec<Tensor>> {
            ls.iter().map(Tensor::from_literal).collect()
        };
        Ok((conv(&self.params)?, conv(&self.m)?, conv(&self.v)?))
    }
}

fn expect_shape(t: &Tensor, shape: &[usize], what: &str) -> Result<()> {
    if t.shape != shape {
        bail!("{what}: shape {:?} != expected {:?}", t.shape, shape);
    }
    Ok(())
}

/// Elements of one batch row of a state lane, validating that the leading
/// dim matches the decode batch.
fn lane_elems(t: &Tensor, batch: usize, leaf: usize) -> Result<usize> {
    match t.shape.first() {
        Some(&b) if b == batch => Ok(t.len() / batch),
        other => bail!(
            "state leaf {leaf}: leading dim {other:?} != decode batch {batch}"
        ),
    }
}
