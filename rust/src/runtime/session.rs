//! Training session: owns the model/optimizer state (as XLA literals) and
//! drives the step/grad/apply/eval programs of one `Bundle`.
//!
//! This is the boundary between the rust coordinator (batches, schedules,
//! telemetry) and the AOT-compiled jax computation. State stays in
//! `xla::Literal`s between steps; only loss + router-load scalars are decoded
//! per step.

use anyhow::{bail, Context, Result};

use crate::runtime::artifact::Bundle;
use crate::runtime::tensor::Tensor;

/// Loss + telemetry decoded from one training step.
#[derive(Debug, Clone)]
pub struct StepOut {
    pub loss: f64,
    /// (num_routers x num_experts) dispatch fractions, row-major.
    pub router_load: Vec<f32>,
}

pub struct Session<'a> {
    pub bundle: &'a Bundle,
    params: Vec<xla::Literal>,
    m: Vec<xla::Literal>,
    v: Vec<xla::Literal>,
    step_count: u64,
}

impl<'a> Session<'a> {
    /// Initialize model params on device from `seed`; optimizer state zeroed.
    pub fn init(bundle: &'a Bundle, seed: i32) -> Result<Session<'a>> {
        let p = bundle.init()?;
        let seed_lit = Tensor::scalar_i32(seed).to_literal()?;
        let params = p.run(&[&seed_lit]).context("init artifact")?;
        let n = bundle.manifest.num_leaves();
        if params.len() != n {
            bail!("init returned {} leaves, manifest says {n}", params.len());
        }
        // Build the zero tensors once, upload twice (m and v) — avoids the
        // per-leaf literal->host->literal round-trip of a naive clone
        // (§Perf L3 log in EXPERIMENTS.md).
        let zero_tensors = bundle.manifest.zeros_like_params();
        let m = zero_tensors.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let v = zero_tensors.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        Ok(Session { bundle, params, m, v, step_count: 0 })
    }

    /// Restore from checkpointed tensors (params, m, v, step_count).
    pub fn restore(
        bundle: &'a Bundle,
        params: &[Tensor],
        m: &[Tensor],
        v: &[Tensor],
        step_count: u64,
    ) -> Result<Session<'a>> {
        let n = bundle.manifest.num_leaves();
        if params.len() != n || m.len() != n || v.len() != n {
            bail!("checkpoint leaf count mismatch");
        }
        let conv = |ts: &[Tensor]| -> Result<Vec<xla::Literal>> {
            ts.iter().map(|t| t.to_literal()).collect()
        };
        Ok(Session {
            bundle,
            params: conv(params)?,
            m: conv(m)?,
            v: conv(v)?,
            step_count,
        })
    }

    pub fn step_count(&self) -> u64 {
        self.step_count
    }

    /// Fused train step on a full (B, T) batch.
    pub fn train_step(&mut self, lr: f32, tokens: &Tensor, targets: &Tensor) -> Result<StepOut> {
        let man = &self.bundle.manifest;
        expect_shape(tokens, &[man.batch_size, man.seq_len], "tokens")?;
        expect_shape(targets, &[man.batch_size, man.seq_len], "targets")?;
        let prog = self.bundle.step()?;
        self.step_count += 1;
        let stepnum = Tensor::scalar_f32(self.step_count as f32).to_literal()?;
        let lr_lit = Tensor::scalar_f32(lr).to_literal()?;
        let tok = tokens.to_literal()?;
        let tgt = targets.to_literal()?;

        let mut inputs: Vec<&xla::Literal> =
            Vec::with_capacity(3 * self.params.len() + 4);
        inputs.extend(self.params.iter());
        inputs.extend(self.m.iter());
        inputs.extend(self.v.iter());
        inputs.push(&stepnum);
        inputs.push(&lr_lit);
        inputs.push(&tok);
        inputs.push(&tgt);

        let mut outs = prog.run(&inputs)?;
        let n = self.params.len();
        if outs.len() != 3 * n + 2 {
            bail!("step returned {} outputs, expected {}", outs.len(), 3 * n + 2);
        }
        let load_lit = outs.pop().unwrap();
        let loss_lit = outs.pop().unwrap();
        self.v = outs.split_off(2 * n);
        self.m = outs.split_off(n);
        self.params = outs;

        Ok(StepOut {
            loss: Tensor::from_literal(&loss_lit)?.item_f32()? as f64,
            router_load: Tensor::from_literal(&load_lit)?.as_f32()?.to_vec(),
        })
    }

    /// Microbatch grad-accumulation path: accumulate over `micro` batches of
    /// (micro_batch, T), then apply once. Returns the mean loss.
    pub fn train_step_accum(
        &mut self,
        lr: f32,
        microbatches: &[(Tensor, Tensor)],
    ) -> Result<f64> {
        if microbatches.is_empty() {
            bail!("no microbatches");
        }
        let man = &self.bundle.manifest;
        let grad = self.bundle.grad()?;
        let apply = self.bundle.apply()?;
        let n = self.params.len();

        let mut gacc: Vec<xla::Literal> = man
            .zeros_like_params()
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let mut loss_sum = 0.0f64;
        for (tokens, targets) in microbatches {
            expect_shape(tokens, &[man.micro_batch, man.seq_len], "micro tokens")?;
            let tok = tokens.to_literal()?;
            let tgt = targets.to_literal()?;
            let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(2 * n + 2);
            inputs.extend(self.params.iter());
            inputs.extend(gacc.iter());
            inputs.push(&tok);
            inputs.push(&tgt);
            let mut outs = grad.run(&inputs)?;
            if outs.len() != n + 1 {
                bail!("grad returned {} outputs, expected {}", outs.len(), n + 1);
            }
            let loss_lit = outs.pop().unwrap();
            gacc = outs;
            loss_sum += Tensor::from_literal(&loss_lit)?.item_f32()? as f64;
        }

        self.step_count += 1;
        let stepnum = Tensor::scalar_f32(self.step_count as f32).to_literal()?;
        let lr_lit = Tensor::scalar_f32(lr).to_literal()?;
        let nmicro = Tensor::scalar_f32(microbatches.len() as f32).to_literal()?;
        let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(4 * n + 3);
        inputs.extend(self.params.iter());
        inputs.extend(self.m.iter());
        inputs.extend(self.v.iter());
        inputs.extend(gacc.iter());
        inputs.push(&stepnum);
        inputs.push(&lr_lit);
        inputs.push(&nmicro);
        let mut outs = apply.run(&inputs)?;
        if outs.len() != 3 * n {
            bail!("apply returned {} outputs, expected {}", outs.len(), 3 * n);
        }
        self.v = outs.split_off(2 * n);
        self.m = outs.split_off(n);
        self.params = outs;
        Ok(loss_sum / microbatches.len() as f64)
    }

    /// Evaluate summed NLL + token count on one (1, L) sequence pair.
    pub fn eval(&self, len: usize, tokens: &Tensor, targets: &Tensor) -> Result<(f64, f64)> {
        expect_shape(tokens, &[1, len], "eval tokens")?;
        let prog = self.bundle.eval(len)?;
        let tok = tokens.to_literal()?;
        let tgt = targets.to_literal()?;
        let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(self.params.len() + 2);
        inputs.extend(self.params.iter());
        inputs.push(&tok);
        inputs.push(&tgt);
        let outs = prog.run(&inputs)?;
        if outs.len() != 2 {
            bail!("eval returned {} outputs, expected 2", outs.len());
        }
        Ok((
            Tensor::from_literal(&outs[0])?.item_f32()? as f64,
            Tensor::from_literal(&outs[1])?.item_f32()? as f64,
        ))
    }

    /// Final-position-only NLL (cloze probe primitive; see Bundle::eval_last).
    pub fn eval_last(&self, len: usize, tokens: &Tensor, targets: &Tensor) -> Result<(f64, f64)> {
        expect_shape(tokens, &[1, len], "eval_last tokens")?;
        let prog = self.bundle.eval_last(len)?;
        let tok = tokens.to_literal()?;
        let tgt = targets.to_literal()?;
        let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(self.params.len() + 2);
        inputs.extend(self.params.iter());
        inputs.push(&tok);
        inputs.push(&tgt);
        let outs = prog.run(&inputs)?;
        if outs.len() != 2 {
            bail!("eval_last returned {} outputs, expected 2", outs.len());
        }
        Ok((
            Tensor::from_literal(&outs[0])?.item_f32()? as f64,
            Tensor::from_literal(&outs[1])?.item_f32()? as f64,
        ))
    }

    /// Copy current state to host tensors (checkpointing).
    pub fn export(&self) -> Result<(Vec<Tensor>, Vec<Tensor>, Vec<Tensor>)> {
        let conv = |ls: &[xla::Literal]| -> Result<Vec<Tensor>> {
            ls.iter().map(Tensor::from_literal).collect()
        };
        Ok((conv(&self.params)?, conv(&self.m)?, conv(&self.v)?))
    }
}


fn expect_shape(t: &Tensor, shape: &[usize], what: &str) -> Result<()> {
    if t.shape != shape {
        bail!("{what}: shape {:?} != expected {:?}", t.shape, shape);
    }
    Ok(())
}
