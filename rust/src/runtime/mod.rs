//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute from the
//! coordinator hot path. See DESIGN.md §2 for the artifact contract.
pub mod artifact;
pub mod session;
pub mod tensor;
