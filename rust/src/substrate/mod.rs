//! Hand-rolled substrates for the offline crate set (DESIGN.md §3):
//! JSON, CLI parsing, RNG, thread pool, bench harness, property testing,
//! logging.
pub mod bench;
pub mod cli;
pub mod json;
pub mod log;
pub mod pool;
pub mod proptest;
pub mod rng;
pub mod sync;
