//! Minimal leveled logger writing to stderr; level from ROM_LOG (error,
//! warn, info, debug; default info). Timestamps are relative to process
//! start (monotonic) — good enough for training logs and greppable.

use std::sync::OnceLock;
use std::time::Instant;

#[derive(PartialEq, PartialOrd, Clone, Copy, Debug)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static START: OnceLock<Instant> = OnceLock::new();
static LEVEL: OnceLock<Level> = OnceLock::new();

pub fn level() -> Level {
    *LEVEL.get_or_init(|| match std::env::var("ROM_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        _ => Level::Info,
    })
}

pub fn log(lvl: Level, msg: &str) {
    if lvl > level() {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
    eprintln!("[{t:9.3}s {lvl:?}] {msg}");
}

#[macro_export]
macro_rules! info { ($($t:tt)*) => { $crate::substrate::log::log($crate::substrate::log::Level::Info, &format!($($t)*)) } }
#[macro_export]
macro_rules! warnln { ($($t:tt)*) => { $crate::substrate::log::log($crate::substrate::log::Level::Warn, &format!($($t)*)) } }
#[macro_export]
macro_rules! debugln { ($($t:tt)*) => { $crate::substrate::log::log($crate::substrate::log::Level::Debug, &format!($($t)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Info < Level::Debug);
    }

    #[test]
    fn log_smoke() {
        log(Level::Info, "hello from test");
    }
}
