//! Property-testing mini-framework (proptest is unavailable offline).
//!
//! `props::check` runs a property over N seeded random cases; on failure it
//! performs greedy input shrinking via the case's seed neighborhood and
//! reports the smallest failing seed. Generators are plain closures over
//! `Rng`, composed with ordinary rust code.

use crate::substrate::rng::Rng;

pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 64, seed: 0xC0FFEE }
    }
}

/// Run `prop(rng)` for `cfg.cases` independent cases. The property returns
/// Ok(()) or Err(description). Panics with the failing seed + description so
/// `cargo test` reports it; rerun with `PROP_SEED=<seed>` to reproduce a
/// single case deterministically.
pub fn check<F>(name: &str, cfg: Config, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    // Deterministic override for reproducing one failing case.
    if let Ok(s) = std::env::var("PROP_SEED") {
        if let Ok(seed) = s.parse::<u64>() {
            let mut rng = Rng::new(seed);
            if let Err(msg) = prop(&mut rng) {
                panic!("property {name} failed at PROP_SEED={seed}: {msg}");
            }
            return;
        }
    }
    for case in 0..cfg.cases {
        let case_seed = cfg.seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(case_seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property {name} failed (case {case}/{}, reproduce with PROP_SEED={case_seed}): {msg}",
                cfg.cases
            );
        }
    }
}

/// Convenience assertion helpers for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr) => {{
        let (a, b) = (&$a, &$b);
        if a != b {
            return Err(format!("{:?} != {:?}", a, b));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check("count", Config { cases: 10, seed: 1 }, |_rng| {
            n += 1;
            Ok(())
        });
        assert_eq!(n, 10);
    }

    #[test]
    #[should_panic(expected = "property demo failed")]
    fn failing_property_panics_with_seed() {
        check("demo", Config::default(), |rng| {
            let x = rng.below(100);
            prop_assert!(x < 90, "x = {x} >= 90");
            Ok(())
        });
    }

    #[test]
    fn generators_compose() {
        check("vec-gen", Config { cases: 32, seed: 2 }, |rng| {
            let len = rng.below(20) as usize;
            let v: Vec<u64> = (0..len).map(|_| rng.below(1000)).collect();
            prop_assert_eq!(v.len(), len);
            Ok(())
        });
    }
}
