//! Sync-primitive shim: `std::sync`/`std::thread` by default, loom's
//! model-checked replacements under `--cfg loom`.
//!
//! `substrate::pool` (and anything else whose interleavings we want to
//! model-check) imports its primitives from here instead of `std`. A normal
//! build re-exports the std types verbatim — zero behavior change, zero
//! cost. A build with `RUSTFLAGS="--cfg loom"` swaps in `loom::sync` /
//! `loom::thread`, and `tests/loom_pool.rs` then explores every
//! interleaving of the pool's submit/join/drop protocols — and of the
//! `reduce_group` rendezvous (the `--dp` gradient-exchange barrier,
//! including member departure mid-barrier) — under `loom::model`.
//!
//! loom has no `mpsc::sync_channel`, so under `cfg(loom)` the `mpsc`
//! submodule provides a hand-rolled bounded channel built on the loom
//! `Mutex`/`Condvar` with the same interface and disconnect semantics as
//! `std::sync::mpsc`: `send` blocks when full and errors once the receiver
//! is gone, `recv` drains buffered items before reporting disconnection,
//! dropping the receiver wakes blocked senders. The pool *logic* (channel
//! close ordering, `InFlight` counting, worker shutdown) is what the models
//! check; the production channel itself stays `std::sync::mpsc`.

#[cfg(not(loom))]
pub use std::sync::{Arc, Condvar, Mutex};
#[cfg(not(loom))]
pub use std::thread;

#[cfg(loom)]
pub use loom::sync::{Arc, Condvar, Mutex};
#[cfg(loom)]
pub use loom::thread;

pub mod mpsc {
    #[cfg(not(loom))]
    pub use std::sync::mpsc::{
        sync_channel, Receiver, RecvError, SendError, SyncSender, TryRecvError,
    };

    #[cfg(loom)]
    pub use loom_chan::{
        sync_channel, Receiver, RecvError, SendError, SyncSender, TryRecvError,
    };

    /// Bounded mpsc over loom primitives (see module docs). Interface and
    /// disconnect behavior mirror `std::sync::mpsc::sync_channel`.
    #[cfg(loom)]
    mod loom_chan {
        use super::super::{Arc, Condvar, Mutex};
        use std::collections::VecDeque;

        #[derive(Debug)]
        pub struct SendError<T>(pub T);

        #[derive(Debug, PartialEq, Eq)]
        pub struct RecvError;

        #[derive(Debug, PartialEq, Eq)]
        pub enum TryRecvError {
            Empty,
            Disconnected,
        }

        struct State<T> {
            q: VecDeque<T>,
            cap: usize,
            senders: usize,
            receiver_alive: bool,
        }

        struct Chan<T> {
            state: Mutex<State<T>>,
            not_empty: Condvar,
            not_full: Condvar,
        }

        pub struct SyncSender<T>(Arc<Chan<T>>);

        pub struct Receiver<T>(Arc<Chan<T>>);

        pub fn sync_channel<T>(cap: usize) -> (SyncSender<T>, Receiver<T>) {
            let chan = Arc::new(Chan {
                state: Mutex::new(State {
                    q: VecDeque::new(),
                    cap: cap.max(1),
                    senders: 1,
                    receiver_alive: true,
                }),
                not_empty: Condvar::new(),
                not_full: Condvar::new(),
            });
            (SyncSender(Arc::clone(&chan)), Receiver(chan))
        }

        impl<T> SyncSender<T> {
            pub fn send(&self, value: T) -> Result<(), SendError<T>> {
                let mut s = self.0.state.lock().unwrap();
                while s.receiver_alive && s.q.len() >= s.cap {
                    s = self.0.not_full.wait(s).unwrap();
                }
                if !s.receiver_alive {
                    return Err(SendError(value));
                }
                s.q.push_back(value);
                drop(s);
                self.0.not_empty.notify_one();
                Ok(())
            }
        }

        impl<T> Clone for SyncSender<T> {
            fn clone(&self) -> Self {
                self.0.state.lock().unwrap().senders += 1;
                SyncSender(Arc::clone(&self.0))
            }
        }

        impl<T> Drop for SyncSender<T> {
            fn drop(&mut self) {
                let mut s = self.0.state.lock().unwrap();
                s.senders -= 1;
                let last = s.senders == 0;
                drop(s);
                if last {
                    // Blocked receivers must observe the disconnect.
                    self.0.not_empty.notify_all();
                }
            }
        }

        impl<T> Receiver<T> {
            pub fn recv(&self) -> Result<T, RecvError> {
                let mut s = self.0.state.lock().unwrap();
                loop {
                    if let Some(v) = s.q.pop_front() {
                        drop(s);
                        self.0.not_full.notify_one();
                        return Ok(v);
                    }
                    if s.senders == 0 {
                        return Err(RecvError);
                    }
                    s = self.0.not_empty.wait(s).unwrap();
                }
            }

            pub fn try_recv(&self) -> Result<T, TryRecvError> {
                let mut s = self.0.state.lock().unwrap();
                if let Some(v) = s.q.pop_front() {
                    drop(s);
                    self.0.not_full.notify_one();
                    return Ok(v);
                }
                if s.senders == 0 {
                    Err(TryRecvError::Disconnected)
                } else {
                    Err(TryRecvError::Empty)
                }
            }
        }

        impl<T> Drop for Receiver<T> {
            fn drop(&mut self) {
                let mut s = self.0.state.lock().unwrap();
                s.receiver_alive = false;
                drop(s);
                // Blocked senders must observe the disconnect.
                self.0.not_full.notify_all();
            }
        }
    }
}
