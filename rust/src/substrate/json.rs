//! Minimal JSON parser/serializer (serde is unavailable in the offline crate
//! set — DESIGN.md §3 substitution table).
//!
//! Supports the full JSON grammar needed by manifests/configs/checkpoints:
//! objects, arrays, strings (with escapes), numbers (f64), booleans, null.
//! Numbers are stored as f64, which is lossless for every integer this repo
//! serializes (< 2^53).

use std::collections::BTreeMap;
use std::fmt;

/// Maximum container nesting accepted by the parser. Every document this
/// repo exchanges (manifests, configs, checkpoint headers, bench records)
/// nests single digits deep; the cap turns adversarially deep input into a
/// parse error instead of a recursion-driven stack overflow.
const MAX_DEPTH: usize = 128;

/// A parsed JSON value. Objects use BTreeMap so serialization is
/// deterministic (stable key order) — the checkpoint format relies on this.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, thiserror::Error)]
pub enum JsonError {
    #[error("json parse error at byte {0}: {1}")]
    Parse(usize, String),
    #[error("json type error: expected {0} at {1}")]
    Type(&'static str, String),
    #[error("json missing key: {0}")]
    Missing(String),
}

impl Json {
    // ---- typed accessors --------------------------------------------------
    pub fn as_f64(&self) -> Result<f64, JsonError> {
        match self {
            Json::Num(n) => Ok(*n),
            other => Err(JsonError::Type("number", other.kind().into())),
        }
    }
    pub fn as_i64(&self) -> Result<i64, JsonError> {
        Ok(self.as_f64()? as i64)
    }
    pub fn as_usize(&self) -> Result<usize, JsonError> {
        Ok(self.as_f64()? as usize)
    }
    pub fn as_bool(&self) -> Result<bool, JsonError> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => Err(JsonError::Type("bool", other.kind().into())),
        }
    }
    pub fn as_str(&self) -> Result<&str, JsonError> {
        match self {
            Json::Str(s) => Ok(s),
            other => Err(JsonError::Type("string", other.kind().into())),
        }
    }
    pub fn as_arr(&self) -> Result<&[Json], JsonError> {
        match self {
            Json::Arr(a) => Ok(a),
            other => Err(JsonError::Type("array", other.kind().into())),
        }
    }
    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>, JsonError> {
        match self {
            Json::Obj(o) => Ok(o),
            other => Err(JsonError::Type("object", other.kind().into())),
        }
    }
    /// Object field access: `j.get("a")?.get("b")?`.
    pub fn get(&self, key: &str) -> Result<&Json, JsonError> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| JsonError::Missing(key.to_string()))
    }
    /// Optional field: None when absent or null.
    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self.as_obj().ok()?.get(key) {
            Some(Json::Null) | None => None,
            Some(v) => Some(v),
        }
    }
    pub fn kind(&self) -> &'static str {
        match self {
            Json::Null => "null",
            Json::Bool(_) => "bool",
            Json::Num(_) => "number",
            Json::Str(_) => "string",
            Json::Arr(_) => "array",
            Json::Obj(_) => "object",
        }
    }

    // ---- constructors ------------------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn num<T: Into<f64>>(n: T) -> Json {
        Json::Num(n.into())
    }
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
    pub fn arr_f64(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
    }
    pub fn arr_usize(v: &[usize]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    // ---- parse -------------------------------------------------------------
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let b = s.as_bytes();
        let mut p = Parser { b, i: 0, depth: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != b.len() {
            return Err(JsonError::Parse(p.i, "trailing content".into()));
        }
        Ok(v)
    }

    /// Parse raw bytes, reporting invalid UTF-8 as a positioned parse error.
    /// Use this for files that may be corrupt (manifests, bench records) —
    /// `parse(&str)` can never see bad UTF-8 because the type rules it out,
    /// so readers going through `read_to_string` lose the byte offset.
    pub fn parse_bytes(b: &[u8]) -> Result<Json, JsonError> {
        let s = std::str::from_utf8(b)
            .map_err(|e| JsonError::Parse(e.valid_up_to(), "invalid utf-8".into()))?;
        Json::parse(s)
    }

    // ---- serialize ---------------------------------------------------------
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn enter(&mut self) -> Result<(), JsonError> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return self.err(&format!("nesting deeper than {MAX_DEPTH}"));
        }
        Ok(())
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn err<T>(&self, msg: &str) -> Result<T, JsonError> {
        Err(JsonError::Parse(self.i, msg.to_string()))
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.i < self.b.len() && self.b[self.i] == c {
            self.i += 1;
            Ok(())
        } else {
            self.err(&format!("expected {:?}", c as char))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        if self.i >= self.b.len() {
            return self.err("unexpected end");
        }
        match self.b[self.i] {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => self.err(&format!("unexpected {:?}", c as char)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            self.err(&format!("expected {word}"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.b[self.i] == b'-' {
            self.i += 1;
        }
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| JsonError::Parse(start, format!("bad number {s:?}: {e}")))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            if self.i >= self.b.len() {
                return self.err("unterminated string");
            }
            match self.b[self.i] {
                b'"' => {
                    self.i += 1;
                    return Ok(out);
                }
                b'\\' => {
                    self.i += 1;
                    if self.i >= self.b.len() {
                        return self.err("bad escape");
                    }
                    let c = self.b[self.i];
                    self.i += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return self.err("bad \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| JsonError::Parse(self.i, "bad \\u".into()))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| JsonError::Parse(self.i, "bad \\u".into()))?;
                            self.i += 4;
                            // Surrogate pairs are not needed by our manifests;
                            // map lone surrogates to the replacement char.
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return self.err("unknown escape"),
                    }
                }
                _ => {
                    // Copy a run of plain bytes (UTF-8 passes through).
                    let start = self.i;
                    while self.i < self.b.len()
                        && self.b[self.i] != b'"'
                        && self.b[self.i] != b'\\'
                    {
                        self.i += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.b[start..self.i]).map_err(
                        |_| JsonError::Parse(start, "invalid utf-8".into()),
                    )?);
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.enter()?;
        let v = self.array_inner();
        self.depth -= 1;
        v
    }

    fn array_inner(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.i < self.b.len() && self.b[self.i] == b']' {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            if self.i >= self.b.len() {
                return self.err("unterminated array");
            }
            match self.b[self.i] {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return self.err("expected , or ]"),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.enter()?;
        let v = self.object_inner();
        self.depth -= 1;
        v
    }

    fn object_inner(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.i < self.b.len() && self.b[self.i] == b'}' {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            if out.contains_key(&k) {
                // Last-wins would silently drop data; every writer in this
                // repo (python json.dump, our BTreeMap serializer) emits
                // unique keys, so a duplicate always means corruption.
                return self.err(&format!("duplicate key {k:?}"));
            }
            out.insert(k, v);
            self.ws();
            if self.i >= self.b.len() {
                return self.err("unterminated object");
            }
            match self.b[self.i] {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return self.err("expected , or }"),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -12.5e2 ").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert!(j.opt("c").is_none());
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str()
                .unwrap(),
            "x"
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,true,null,"s"],"n":-3,"o":{"k":"v"}}"#;
        let j = Json::parse(src).unwrap();
        let out = j.to_string();
        assert_eq!(Json::parse(&out).unwrap(), j);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn parse_bytes_matches_parse_on_valid_input() {
        let src = r#"{"a": [1, 2], "b": "x"}"#;
        assert_eq!(Json::parse_bytes(src.as_bytes()).unwrap(), Json::parse(src).unwrap());
    }

    #[test]
    fn rejects_invalid_utf8_with_byte_offset() {
        // 0xFF can never appear in well-formed UTF-8; it sits at byte 8.
        let bytes = b"{\"k\": \"a\xFFb\"}";
        let err = Json::parse_bytes(bytes).unwrap_err().to_string();
        assert!(err.contains("invalid utf-8"), "{err}");
        assert!(err.contains("byte 8"), "{err}");
    }

    #[test]
    fn rejects_duplicate_keys() {
        let err = Json::parse(r#"{"a": 1, "a": 2}"#).unwrap_err().to_string();
        assert!(err.contains("duplicate key \"a\""), "{err}");
        // Nested objects are checked too.
        assert!(Json::parse(r#"{"o": {"x": 1, "x": 1}}"#).is_err());
        // Same key at different depths is fine.
        assert!(Json::parse(r#"{"a": {"a": 1}}"#).is_ok());
    }

    #[test]
    fn rejects_deep_nesting_instead_of_overflowing() {
        let deep = "[".repeat(500) + &"]".repeat(500);
        let err = Json::parse(&deep).unwrap_err().to_string();
        assert!(err.contains("nesting deeper than"), "{err}");

        let mixed = "{\"a\":".repeat(300) + "1" + &"}".repeat(300);
        assert!(Json::parse(&mixed).is_err());

        // Well inside the cap still parses.
        let ok = "[".repeat(64) + "1" + &"]".repeat(64);
        assert!(Json::parse(&ok).is_ok());
    }

    #[test]
    fn int_precision() {
        // Every count this repo serializes stays integral through a roundtrip.
        let j = Json::parse("9007199254740991").unwrap();
        assert_eq!(j.to_string(), "9007199254740991");
    }
}
