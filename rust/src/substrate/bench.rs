//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Warmup + timed iterations, robust statistics (median / p10 / p90 over
//! per-iteration wall times), and a one-line report compatible with
//! `cargo bench` custom-harness targets. Table/figure benches use `Reporter`
//! to print paper-style rows.

use std::time::Instant;

#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub median_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
    pub mean_ns: f64,
}

impl BenchStats {
    pub fn print(&self) {
        println!(
            "bench {:<40} {:>10} med {:>12} p10 {:>12} p90 {:>12} ({} iters)",
            self.name,
            fmt_ns(self.median_ns),
            fmt_ns(self.p10_ns),
            fmt_ns(self.p90_ns),
            fmt_ns(self.mean_ns),
            self.iters
        );
    }

    pub fn median_secs(&self) -> f64 {
        self.median_ns / 1e9
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

/// Run `f` for `warmup` unmeasured + `iters` measured iterations.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_nanos() as f64);
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let q = |p: f64| times[((times.len() - 1) as f64 * p) as usize];
    let stats = BenchStats {
        name: name.to_string(),
        iters: times.len(),
        median_ns: q(0.5),
        p10_ns: q(0.1),
        p90_ns: q(0.9),
        mean_ns: times.iter().sum::<f64>() / times.len() as f64,
    };
    stats.print();
    stats
}

/// Time a single run of `f` (for end-to-end experiment benches where one
/// iteration is already seconds long).
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// The machine-readable trajectory record shared by `bench_runtime` and
/// `bench_generate`: ROM_BENCH_JSON override, else `BENCH_runtime.json` at
/// the repo root next to ROADMAP.md (CARGO_MANIFEST_DIR is `<repo>/rust`).
/// Schema: EXPERIMENTS.md §BENCH_runtime.json schema.
pub fn bench_json_path() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("ROM_BENCH_JSON") {
        return std::path::PathBuf::from(p);
    }
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../BENCH_runtime.json")
}

/// Numeric env-var knob with a default (bench iteration counts and sizes).
pub fn env_u64(key: &str, default: u64) -> u64 {
    std::env::var(key).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

/// Paper-style table printer: fixed-width columns, one row per variant.
pub struct Reporter {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Reporter {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Reporter {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "ragged reporter row");
        self.rows.push(cells.to_vec());
    }

    /// The accumulated rows, in insertion order — the scheduler determinism
    /// guard compares these across `--jobs` settings byte for byte.
    pub fn rows(&self) -> &[Vec<String>] {
        &self.rows
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        println!("\n=== {} ===", self.title);
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i == 0 {
                    s.push_str(&format!("{:<w$}", c, w = widths[i] + 2));
                } else {
                    s.push_str(&format!("{:>w$}", c, w = widths[i] + 2));
                }
            }
            println!("{s}");
        };
        line(&self.headers);
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        for row in &self.rows {
            line(row);
        }
        println!();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_ordered_quantiles() {
        let s = bench("noop", 2, 20, || {
            std::hint::black_box(1 + 1);
        });
        assert!(s.p10_ns <= s.median_ns && s.median_ns <= s.p90_ns);
        assert_eq!(s.iters, 20);
    }

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(500.0), "500ns");
        assert_eq!(fmt_ns(1500.0), "1.50us");
        assert_eq!(fmt_ns(2.5e6), "2.50ms");
        assert_eq!(fmt_ns(3.2e9), "3.200s");
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn reporter_rejects_ragged_rows() {
        let mut r = Reporter::new("t", &["a", "b"]);
        r.row(&["only-one".to_string()]);
    }

    #[test]
    fn reporter_prints() {
        let mut r = Reporter::new("demo", &["arch", "ppl"]);
        r.row(&["mamba".into(), "10.7".into()]);
        r.row(&["rom".into(), "9.5".into()]);
        r.print(); // smoke: no panic
    }
}
