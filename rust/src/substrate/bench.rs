//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Warmup + timed iterations, robust statistics (median / p10 / p90 over
//! per-iteration wall times), and a one-line report compatible with
//! `cargo bench` custom-harness targets. Table/figure benches use `Reporter`
//! to print paper-style rows.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::substrate::json::Json;

#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub median_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
    pub mean_ns: f64,
}

impl BenchStats {
    pub fn print(&self) {
        println!(
            "bench {:<40} {:>10} med {:>12} p10 {:>12} p90 {:>12} ({} iters)",
            self.name,
            fmt_ns(self.median_ns),
            fmt_ns(self.p10_ns),
            fmt_ns(self.p90_ns),
            fmt_ns(self.mean_ns),
            self.iters
        );
    }

    pub fn median_secs(&self) -> f64 {
        self.median_ns / 1e9
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

/// Run `f` for `warmup` unmeasured + `iters` measured iterations.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_nanos() as f64);
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let q = |p: f64| times[((times.len() - 1) as f64 * p) as usize];
    let stats = BenchStats {
        name: name.to_string(),
        iters: times.len(),
        median_ns: q(0.5),
        p10_ns: q(0.1),
        p90_ns: q(0.9),
        mean_ns: times.iter().sum::<f64>() / times.len() as f64,
    };
    stats.print();
    stats
}

/// Time a single run of `f` (for end-to-end experiment benches where one
/// iteration is already seconds long).
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// The machine-readable trajectory record shared by `bench_runtime` and
/// `bench_generate`: ROM_BENCH_JSON override, else `BENCH_runtime.json` at
/// the repo root next to ROADMAP.md (CARGO_MANIFEST_DIR is `<repo>/rust`).
/// Schema: EXPERIMENTS.md §BENCH_runtime.json schema.
pub fn bench_json_path() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("ROM_BENCH_JSON") {
        return std::path::PathBuf::from(p);
    }
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../BENCH_runtime.json")
}

/// Numeric env-var knob with a default (bench iteration counts and sizes).
pub fn env_u64(key: &str, default: u64) -> u64 {
    std::env::var(key).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

// ---- Atomic trajectory-record merging --------------------------------------
// `BENCH_runtime.json` is co-owned by several bench binaries (and potentially
// several concurrent runs). Every writer goes through `merge_bench_json`:
// read the current record under a lock, apply the caller's update, publish
// via tmp-file + rename (the same atomic-publish pattern as checkpoint
// saves). A corrupt existing file is an ERROR — the perf trajectory is the
// deliverable, so it must never be silently reset to `{}`.

/// Same-process writer serialization (threads of one bench process).
static MERGE_GUARD: Mutex<()> = Mutex::new(());
/// Uniquifies tmp-file names so concurrent processes never collide.
static MERGE_SEQ: AtomicU64 = AtomicU64::new(0);

/// A lock file held for the read-modify-write window. Best-effort cross-
/// process exclusion via `create_new`; released (removed) on drop so error
/// paths cannot leak a held lock.
struct MergeLock {
    path: PathBuf,
}

impl MergeLock {
    fn acquire(target: &Path) -> Result<MergeLock> {
        let path = lock_path(target);
        let deadline = Instant::now() + Duration::from_secs(20);
        loop {
            match std::fs::OpenOptions::new().write(true).create_new(true).open(&path) {
                Ok(_) => return Ok(MergeLock { path }),
                Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                    // A holder that crashed mid-merge leaves the lock behind;
                    // steal it once it is clearly stale (merges take ms).
                    if let Ok(meta) = std::fs::metadata(&path) {
                        let stale = meta
                            .modified()
                            .ok()
                            .and_then(|m| m.elapsed().ok())
                            .is_some_and(|age| age > Duration::from_secs(10));
                        if stale {
                            let _ = std::fs::remove_file(&path);
                            continue;
                        }
                    }
                    if Instant::now() > deadline {
                        bail!(
                            "timed out waiting for bench-merge lock {} — remove it \
                             if no bench is running",
                            path.display()
                        );
                    }
                    std::thread::sleep(Duration::from_millis(20));
                }
                Err(e) => {
                    return Err(e)
                        .with_context(|| format!("creating lock {}", path.display()))
                }
            }
        }
    }
}

impl Drop for MergeLock {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.path);
    }
}

fn lock_path(target: &Path) -> PathBuf {
    let mut name = target.file_name().unwrap_or_default().to_os_string();
    name.push(".lock");
    target.with_file_name(name)
}

/// Read-modify-write one flat JSON object file atomically and loss-proof:
///
/// - concurrent writers serialize on a process mutex + on-disk lock file, so
///   two benches merging disjoint fields both land;
/// - the update is published via tmp-file + `std::fs::rename`, so a reader
///   (or a crash mid-write) never observes a partial file;
/// - a missing file starts from an empty record, but an existing file that
///   fails to parse as a JSON object is a hard error — never silently
///   replaced (a whitespace-only file counts as empty, not corrupt).
pub fn merge_bench_json(
    path: &Path,
    update: impl FnOnce(&mut BTreeMap<String, Json>),
) -> Result<()> {
    let _guard = MERGE_GUARD.lock().unwrap_or_else(|e| e.into_inner());
    let _lock = MergeLock::acquire(path)?;

    let mut map = match std::fs::read_to_string(path) {
        Ok(text) if text.trim().is_empty() => BTreeMap::new(),
        Ok(text) => match Json::parse(&text) {
            Ok(Json::Obj(m)) => m,
            Ok(other) => bail!(
                "{}: expected a JSON object, found {} — refusing to overwrite \
                 the perf trajectory (fix or delete the file)",
                path.display(),
                other.kind()
            ),
            Err(e) => bail!(
                "{}: unparseable JSON ({e}) — refusing to overwrite the perf \
                 trajectory (fix or delete the file)",
                path.display()
            ),
        },
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => BTreeMap::new(),
        Err(e) => return Err(e).with_context(|| format!("reading {}", path.display())),
    };

    update(&mut map);

    let seq = MERGE_SEQ.fetch_add(1, Ordering::Relaxed);
    let mut tmp_name = path.file_name().unwrap_or_default().to_os_string();
    tmp_name.push(format!(".tmp.{}.{seq}", std::process::id()));
    let tmp = path.with_file_name(tmp_name);
    {
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        std::io::Write::write_all(&mut f, Json::Obj(map).to_string().as_bytes())?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, path)
        .with_context(|| format!("publishing {}", path.display()))?;
    Ok(())
}

/// Paper-style table printer: fixed-width columns, one row per variant.
pub struct Reporter {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Reporter {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Reporter {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "ragged reporter row");
        self.rows.push(cells.to_vec());
    }

    /// The accumulated rows, in insertion order — the scheduler determinism
    /// guard compares these across `--jobs` settings byte for byte.
    pub fn rows(&self) -> &[Vec<String>] {
        &self.rows
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        println!("\n=== {} ===", self.title);
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i == 0 {
                    s.push_str(&format!("{:<w$}", c, w = widths[i] + 2));
                } else {
                    s.push_str(&format!("{:>w$}", c, w = widths[i] + 2));
                }
            }
            println!("{s}");
        };
        line(&self.headers);
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        for row in &self.rows {
            line(row);
        }
        println!();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_ordered_quantiles() {
        let s = bench("noop", 2, 20, || {
            std::hint::black_box(1 + 1);
        });
        assert!(s.p10_ns <= s.median_ns && s.median_ns <= s.p90_ns);
        assert_eq!(s.iters, 20);
    }

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(500.0), "500ns");
        assert_eq!(fmt_ns(1500.0), "1.50us");
        assert_eq!(fmt_ns(2.5e6), "2.50ms");
        assert_eq!(fmt_ns(3.2e9), "3.200s");
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn reporter_rejects_ragged_rows() {
        let mut r = Reporter::new("t", &["a", "b"]);
        r.row(&["only-one".to_string()]);
    }

    #[test]
    fn reporter_prints() {
        let mut r = Reporter::new("demo", &["arch", "ppl"]);
        r.row(&["mamba".into(), "10.7".into()]);
        r.row(&["rom".into(), "9.5".into()]);
        r.print(); // smoke: no panic
    }

    fn merge_dir(test: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("rom_bench_merge_{}_{test}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn read_obj(path: &Path) -> BTreeMap<String, Json> {
        match Json::parse(&std::fs::read_to_string(path).unwrap()).unwrap() {
            Json::Obj(m) => m,
            other => panic!("expected object, got {}", other.kind()),
        }
    }

    #[test]
    fn merge_creates_then_preserves_other_fields() {
        let dir = merge_dir("create");
        let path = dir.join("BENCH.json");
        merge_bench_json(&path, |m| {
            m.insert("a".into(), Json::num(1.0));
        })
        .unwrap();
        merge_bench_json(&path, |m| {
            m.insert("b".into(), Json::num(2.0));
        })
        .unwrap();
        let m = read_obj(&path);
        assert_eq!(m.get("a"), Some(&Json::Num(1.0)));
        assert_eq!(m.get("b"), Some(&Json::Num(2.0)));
        // No tmp or lock residue after a clean merge.
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name() != "BENCH.json")
            .collect();
        assert!(leftovers.is_empty(), "residue: {leftovers:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn merge_rejects_corrupt_input_without_touching_it() {
        let dir = merge_dir("corrupt");
        let path = dir.join("BENCH.json");
        std::fs::write(&path, "{\"a\": 1").unwrap(); // truncated write
        let err = merge_bench_json(&path, |m| {
            m.insert("b".into(), Json::num(2.0));
        })
        .unwrap_err();
        assert!(err.to_string().contains("refusing"), "got: {err:#}");
        // The corrupt evidence survives for inspection — never reset to {}.
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "{\"a\": 1");

        // A non-object top level is equally fatal.
        std::fs::write(&path, "[1, 2]").unwrap();
        assert!(merge_bench_json(&path, |_| {}).is_err());

        // Whitespace-only counts as an empty record, not corruption.
        std::fs::write(&path, "  \n").unwrap();
        merge_bench_json(&path, |m| {
            m.insert("c".into(), Json::num(3.0));
        })
        .unwrap();
        assert_eq!(read_obj(&path).get("c"), Some(&Json::Num(3.0)));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn concurrent_merges_lose_nothing() {
        // Two threads interleave read-modify-write cycles on disjoint field
        // sets; every field must land (the lost-update race this helper
        // exists to prevent).
        let dir = merge_dir("concurrent");
        let path = dir.join("BENCH.json");
        let per_thread = 40usize;
        let threads: Vec<_> = (0..2)
            .map(|t| {
                let path = path.clone();
                std::thread::spawn(move || {
                    for i in 0..per_thread {
                        merge_bench_json(&path, |m| {
                            m.insert(format!("t{t}_{i}"), Json::num(i as f64));
                        })
                        .unwrap();
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        let m = read_obj(&path);
        assert_eq!(m.len(), 2 * per_thread, "fields lost: have {}", m.len());
        for t in 0..2 {
            for i in 0..per_thread {
                assert!(m.contains_key(&format!("t{t}_{i}")), "missing t{t}_{i}");
            }
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn merge_waits_out_a_foreign_lock() {
        // A lock held by another writer delays the merge instead of failing
        // it: the holder releases after 50ms and the merge then lands.
        let dir = merge_dir("stale");
        let path = dir.join("BENCH.json");
        let lock = lock_path(&path);
        std::fs::write(&lock, "").unwrap();
        let lock2 = lock.clone();
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(50));
            std::fs::remove_file(&lock2).unwrap();
        });
        merge_bench_json(&path, |m| {
            m.insert("after_wait".into(), Json::num(1.0));
        })
        .unwrap();
        t.join().unwrap();
        assert!(read_obj(&path).contains_key("after_wait"));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
