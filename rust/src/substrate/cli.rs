//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Grammar: `prog <subcommand> [positional...] [--flag] [--key value]`.
//! Flags may also be written `--key=value`.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse raw args (excluding argv[0]). `known_flags` lists boolean
    /// switches that never consume a following value.
    pub fn parse(raw: &[String], known_flags: &[&str]) -> Args {
        let mut out = Args::default();
        let mut i = 0;
        while i < raw.len() {
            let a = &raw[i];
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if known_flags.contains(&stripped) {
                    out.flags.push(stripped.to_string());
                } else if i + 1 < raw.len() && !raw[i + 1].starts_with("--") {
                    out.options.insert(stripped.to_string(), raw[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(a.clone());
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        out
    }

    pub fn from_env(known_flags: &[&str]) -> Args {
        let raw: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&raw, known_flags)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// A typed OPTIONAL option: `Ok(None)` when absent, `Ok(Some(parsed))`
    /// when present. Unlike the defaulting `get_*` family, a present but
    /// malformed value is an error naming the flag — never a silent default.
    pub fn get_opt<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>, String> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => {
                v.parse().map(Some).map_err(|_| format!("--{key}: bad value {v:?}"))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_and_options() {
        let a = Args::parse(
            &s(&["train", "rom-e2e", "--steps", "100", "--lr=0.001", "--quiet"]),
            &["quiet"],
        );
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.positional, vec!["rom-e2e"]);
        assert_eq!(a.get_usize("steps", 0), 100);
        assert_eq!(a.get_f64("lr", 0.0), 0.001);
        assert!(a.has_flag("quiet"));
    }

    #[test]
    fn unknown_trailing_flag_is_boolean() {
        let a = Args::parse(&s(&["x", "--verbose"]), &[]);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn defaults() {
        let a = Args::parse(&s(&[]), &[]);
        assert!(a.subcommand.is_none());
        assert_eq!(a.get_or("missing", "d"), "d");
        assert_eq!(a.get_usize("n", 7), 7);
    }

    #[test]
    fn typed_optional_values() {
        let a = Args::parse(&s(&["x", "--stop", "5", "--queue", "oops"]), &[]);
        assert_eq!(a.get_opt::<i32>("stop"), Ok(Some(5)));
        assert_eq!(a.get_opt::<i32>("missing"), Ok(None));
        let err = a.get_opt::<usize>("queue").unwrap_err();
        assert!(err.contains("--queue") && err.contains("oops"));
    }

    #[test]
    fn negative_number_value() {
        // "--key value" where value starts with '-' but not '--'.
        let a = Args::parse(&s(&["x", "--delta", "-3.5"]), &[]);
        assert_eq!(a.get_f64("delta", 0.0), -3.5);
    }
}
