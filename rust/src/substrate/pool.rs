//! Fixed-size thread pool + bounded prefetch channels (tokio is not in the
//! offline crate set; threads + std::sync::mpsc satisfy the coordinator's
//! needs: data prefetch, device encode, request-line pumping, and telemetry
//! I/O off the training hot path).
//!
//! This module is the repo's single home for spawned threads (the lint pass
//! of `rom analyze` enforces it; `std::thread::scope` elsewhere is fine —
//! scoped threads cannot leak). Every primitive here comes from
//! `substrate::sync`, the shim that swaps in loom's model-checked
//! `Mutex`/`Condvar`/`thread` under `RUSTFLAGS="--cfg loom"`; see
//! `tests/loom_pool.rs` for the exhaustive submit/join/drop interleaving
//! models of `ThreadPool`, `Prefetcher`, `Pipeline` and the
//! rendezvous-reduce group (`reduce_group`) behind `rom train --dp`.

use std::io::BufRead;

use crate::substrate::sync::mpsc::{sync_channel, Receiver, SyncSender};
use crate::substrate::sync::thread::JoinHandle;
use crate::substrate::sync::{thread, Arc, Condvar, Mutex};

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Job counter shared between submitters, workers and `join`: a mutex-guarded
/// count plus a condvar signaled when it reaches zero (no busy-wait).
struct InFlight {
    count: Mutex<usize>,
    all_done: Condvar,
}

impl InFlight {
    fn incr(&self) {
        *self.count.lock().unwrap() += 1;
    }

    fn decr(&self) {
        let mut n = self.count.lock().unwrap();
        *n -= 1;
        if *n == 0 {
            self.all_done.notify_all();
        }
    }

    fn wait_zero(&self) {
        let mut n = self.count.lock().unwrap();
        while *n != 0 {
            n = self.all_done.wait(n).unwrap();
        }
    }
}

/// Work-queue thread pool. Jobs run FIFO; `join` blocks until the queue
/// drains and all workers are idle.
pub struct ThreadPool {
    tx: Option<SyncSender<Job>>,
    workers: Vec<JoinHandle<()>>,
    in_flight: Arc<InFlight>,
}

impl ThreadPool {
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let (tx, rx) = sync_channel::<Job>(n * 4);
        let rx = Arc::new(Mutex::new(rx));
        let in_flight =
            Arc::new(InFlight { count: Mutex::new(0), all_done: Condvar::new() });
        let workers = (0..n)
            .map(|_| {
                let rx = Arc::clone(&rx);
                let in_flight = Arc::clone(&in_flight);
                thread::spawn(move || loop {
                    let job = {
                        let guard = rx.lock().unwrap();
                        guard.recv()
                    };
                    match job {
                        Ok(job) => {
                            job();
                            in_flight.decr();
                        }
                        Err(_) => break, // sender dropped: shut down
                    }
                })
            })
            .collect();
        ThreadPool { tx: Some(tx), workers, in_flight }
    }

    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.in_flight.incr();
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("worker panicked");
    }

    /// Block until all submitted jobs completed (condvar wait, not a spin).
    pub fn join(&self) {
        self.in_flight.wait_zero();
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.tx.take(); // close the channel; workers exit on recv Err
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Bounded single-producer prefetcher: a background thread runs `make()`
/// repeatedly and parks results in a channel of depth `depth`, overlapping
/// host-side batch assembly with device execution.
pub struct Prefetcher<T: Send + 'static> {
    rx: Receiver<T>,
    worker: Option<JoinHandle<()>>,
}

impl<T: Send + 'static> Prefetcher<T> {
    pub fn new<F>(depth: usize, mut make: F) -> Self
    where
        F: FnMut() -> Option<T> + Send + 'static,
    {
        let (tx, rx) = sync_channel(depth.max(1));
        let worker = thread::spawn(move || {
            while let Some(item) = make() {
                if tx.send(item).is_err() {
                    break; // consumer dropped
                }
            }
        });
        Prefetcher { rx, worker: Some(worker) }
    }

    /// Next prefetched item; None when the producer is exhausted.
    pub fn next(&self) -> Option<T> {
        self.rx.recv().ok()
    }
}

impl<T: Send + 'static> Drop for Prefetcher<T> {
    fn drop(&mut self) {
        // A producer blocked in `send` wakes with Err the moment `rx` above
        // goes away, so joining here cannot hang; it bounds the wait to at
        // most one in-progress `make()` and leaves no detached thread.
        let worker = self.worker.take();
        drop(std::mem::replace(&mut self.rx, sync_channel(1).1));
        if let Some(w) = worker {
            let _ = w.join();
        }
    }
}

/// Two-stage prefetch pipeline: stage 1 runs `make()` (e.g. window assembly),
/// stage 2 runs `convert()` on each item (e.g. `Tensor -> xla::Literal`
/// encode). Each stage owns a thread and a bounded channel of depth `depth`,
/// so with `depth >= 2` the pipeline is double-buffered: the consumer drains
/// device-ready items while assembly of batch k+1 and encode of batch k
/// proceed concurrently. Item order is preserved end to end (single thread
/// per stage, FIFO channels).
pub struct Pipeline<T: Send + 'static> {
    rx: Receiver<T>,
    stage1: Option<JoinHandle<()>>,
    stage2: Option<JoinHandle<()>>,
}

impl<T: Send + 'static> Pipeline<T> {
    pub fn new<U, F, G>(depth: usize, mut make: F, mut convert: G) -> Self
    where
        U: Send + 'static,
        F: FnMut() -> Option<U> + Send + 'static,
        G: FnMut(U) -> T + Send + 'static,
    {
        let depth = depth.max(1);
        let (tx1, rx1) = sync_channel::<U>(depth);
        let (tx2, rx2) = sync_channel::<T>(depth);
        let stage1 = thread::spawn(move || {
            while let Some(item) = make() {
                if tx1.send(item).is_err() {
                    break; // stage 2 gone: consumer dropped
                }
            }
        });
        let stage2 = thread::spawn(move || {
            while let Ok(item) = rx1.recv() {
                if tx2.send(convert(item)).is_err() {
                    break; // consumer dropped
                }
            }
        });
        Pipeline { rx: rx2, stage1: Some(stage1), stage2: Some(stage2) }
    }

    /// Next device-ready item; None when stage 1 is exhausted and the
    /// pipeline has drained.
    pub fn next(&self) -> Option<T> {
        self.rx.recv().ok()
    }
}

impl<T: Send + 'static> Drop for Pipeline<T> {
    fn drop(&mut self) {
        // Shutdown ordering: dropping the consumer end unblocks stage 2
        // (send Err), whose exit drops rx1 and unblocks stage 1 in turn —
        // so joining 2 then 1 always terminates, with no detached threads.
        let (s1, s2) = (self.stage1.take(), self.stage2.take());
        drop(std::mem::replace(&mut self.rx, sync_channel(1).1));
        if let Some(s) = s2 {
            let _ = s.join();
        }
        if let Some(s) = s1 {
            let _ = s.join();
        }
    }
}

/// Best-effort stringification of a caught panic payload (`&str` and
/// `String` cover every `panic!` in this crate; anything else reports as
/// opaque). Lives here because it pairs with every `catch_unwind` that
/// guards the pool's in-flight accounting — a panicking pool job must be
/// converted to an error, never allowed to unwind a worker thread.
pub fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "opaque panic payload".to_string()
    }
}

/// Error surfaced by [`ReduceMember::reduce`] when the group can no longer
/// complete a round: some member departed (was dropped, or its thread
/// unwound) before contributing or collecting. Callers treat this as "a
/// peer replica died" and bail out instead of blocking forever.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReduceError;

impl std::fmt::Display for ReduceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "reduce group member departed before the round completed")
    }
}

impl std::error::Error for ReduceError {}

struct ReduceState<T, R> {
    /// One slot per rank. Filled in arrival order, *drained in rank order*
    /// by the member that completes the round — the fold therefore always
    /// sees contributions rank-ordered, independent of thread scheduling.
    slots: Vec<Option<T>>,
    arrived: usize,
    /// Folded result of the current round, shared until every member took it.
    result: Option<Arc<R>>,
    taken: usize,
    /// Round counter; bumping it is the "round complete" broadcast.
    round: u64,
    /// Set by `ReduceMember::drop`: the group can never complete again.
    departed: bool,
}

struct ReduceShared<T, R> {
    state: Mutex<ReduceState<T, R>>,
    cv: Condvar,
    #[allow(clippy::type_complexity)]
    fold: Box<dyn Fn(Vec<T>) -> R + Send + Sync>,
    world: usize,
}

/// One rank's handle into a fixed-membership rendezvous-reduce group — the
/// host-side gradient exchange primitive behind `rom train --dp K`.
///
/// All `world` members call [`reduce`](Self::reduce) once per round; the
/// last arriver folds the contributions **in rank order** (slot order, not
/// arrival order — the fixed association that makes a floating-point sum
/// deterministic and world-size-invariant) outside the lock, and every
/// member receives the same `Arc` of the folded result. Rounds repeat for
/// the life of the group.
///
/// Dropping a member — normally, or by unwinding out of a panicking replica
/// — marks the group departed: every member blocked in `reduce` and every
/// later call gets `Err(ReduceError)` instead of deadlocking on a barrier
/// that can never fill. Modeled under loom in `tests/loom_pool.rs`
/// (`reduce_*` models: joiner drops mid-barrier, reducer unwinds
/// mid-stream).
pub struct ReduceMember<T, R> {
    rank: usize,
    shared: Arc<ReduceShared<T, R>>,
}

/// Build a `world`-member reduce group; member `i` of the returned vec is
/// rank `i`. `fold` receives the round's contributions in rank order.
pub fn reduce_group<T, R, F>(world: usize, fold: F) -> Vec<ReduceMember<T, R>>
where
    T: Send,
    R: Send + Sync,
    F: Fn(Vec<T>) -> R + Send + Sync + 'static,
{
    assert!(world >= 1, "reduce group needs at least one member");
    let shared = Arc::new(ReduceShared {
        state: Mutex::new(ReduceState {
            slots: (0..world).map(|_| None).collect(),
            arrived: 0,
            result: None,
            taken: 0,
            round: 0,
            departed: false,
        }),
        cv: Condvar::new(),
        fold: Box::new(fold),
        world,
    });
    (0..world)
        .map(|rank| ReduceMember { rank, shared: Arc::clone(&shared) })
        .collect()
}

impl<T, R> ReduceMember<T, R> {
    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn world(&self) -> usize {
        self.shared.world
    }

    /// Contribute this rank's value and block until the round's rank-ordered
    /// fold is available. Errors (now and forever) once any member departed.
    pub fn reduce(&self, value: T) -> Result<Arc<R>, ReduceError> {
        let sh = &*self.shared;
        let mut s = sh.state.lock().unwrap();
        if s.departed {
            return Err(ReduceError);
        }
        debug_assert!(
            s.slots[self.rank].is_none(),
            "rank {} reduced twice in one round",
            self.rank
        );
        s.slots[self.rank] = Some(value);
        s.arrived += 1;
        if s.arrived == sh.world {
            // Last arriver completes the round: drain slots in rank order
            // and fold outside the lock (gradient sums take milliseconds).
            debug_assert!(s.result.is_none(), "previous round not fully collected");
            let contributions: Vec<T> =
                s.slots.iter_mut().map(|slot| slot.take().expect("slot filled")).collect();
            s.arrived = 0;
            drop(s);
            let folded = (sh.fold)(contributions);
            s = sh.state.lock().unwrap();
            s.result = Some(Arc::new(folded));
            s.taken = 0;
            s.round = s.round.wrapping_add(1);
            sh.cv.notify_all();
        } else {
            let my_round = s.round;
            while !s.departed && s.round == my_round {
                s = sh.cv.wait(s).unwrap();
            }
            if s.round == my_round {
                return Err(ReduceError); // departed before the round filled
            }
        }
        let result = Arc::clone(s.result.as_ref().expect("round complete without result"));
        s.taken += 1;
        if s.taken == sh.world {
            // Last collector clears the way for the next round's fold.
            s.result = None;
        }
        Ok(result)
    }
}

impl<T, R> Drop for ReduceMember<T, R> {
    fn drop(&mut self) {
        let mut s = self.shared.state.lock().unwrap();
        s.departed = true;
        self.shared.cv.notify_all();
    }
}

/// Reader-thread line pump: stream lines from a reader over a bounded
/// channel, so a slow consumer backpressures the producer instead of
/// buffering unboundedly. This is the stdin/file request pump `rom serve`
/// uses; it lives here so every spawned thread in the crate stays inside
/// this module (the `rom analyze` lint enforces that confinement).
///
/// The pump stops at EOF or on the first I/O error (returned through the
/// handle); dropping the receiver stops it at the next line.
pub fn line_pump(
    source: Box<dyn BufRead + Send>,
    depth: usize,
) -> (Receiver<String>, JoinHandle<std::io::Result<()>>) {
    let (tx, rx) = sync_channel::<String>(depth.max(1));
    let handle = thread::spawn(move || -> std::io::Result<()> {
        for line in source.lines() {
            if tx.send(line?).is_err() {
                break; // pump gone — stop reading
            }
        }
        Ok(())
    });
    (rx, handle)
}

// Unit tests run real std threads, so they are meaningless (and would
// panic outside `loom::model`) in a `--cfg loom` build; the loom models
// live in tests/loom_pool.rs.
#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn pool_join_then_reuse() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        for round in 0..3 {
            for _ in 0..10 {
                let c = Arc::clone(&counter);
                pool.submit(move || {
                    c.fetch_add(1, Ordering::Relaxed);
                });
            }
            pool.join();
            assert_eq!(counter.load(Ordering::Relaxed), (round + 1) * 10);
        }
    }

    #[test]
    fn pool_join_waits_for_slow_jobs() {
        // join must actually block on the condvar until a deliberately slow
        // job finishes, not return early on an empty queue snapshot.
        let pool = ThreadPool::new(1);
        let counter = Arc::new(AtomicU64::new(0));
        let c = Arc::clone(&counter);
        pool.submit(move || {
            std::thread::sleep(std::time::Duration::from_millis(50));
            c.fetch_add(1, Ordering::Relaxed);
        });
        pool.join();
        assert_eq!(counter.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn pool_join_on_idle_pool_returns() {
        let pool = ThreadPool::new(2);
        pool.join(); // no jobs submitted: must not deadlock
    }

    #[test]
    fn prefetcher_yields_in_order_and_terminates() {
        let mut n = 0u32;
        let pf = Prefetcher::new(2, move || {
            n += 1;
            if n <= 5 {
                Some(n)
            } else {
                None
            }
        });
        let got: Vec<u32> = std::iter::from_fn(|| pf.next()).collect();
        assert_eq!(got, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn pipeline_preserves_order_and_terminates() {
        let mut n = 0u32;
        let pl = Pipeline::new(
            2,
            move || {
                n += 1;
                if n <= 20 {
                    Some(n)
                } else {
                    None
                }
            },
            |x| x * 10,
        );
        let got: Vec<u32> = std::iter::from_fn(|| pl.next()).collect();
        assert_eq!(got, (1..=20).map(|x| x * 10).collect::<Vec<_>>());
    }

    #[test]
    fn pipeline_stages_overlap() {
        // Stage 1 marks the highest item it has produced; by the time the
        // consumer sees item k, stage 1 must have run ahead of it (double
        // buffering), proving the stages are not in lockstep with the consumer.
        let produced = Arc::new(AtomicU64::new(0));
        let p = Arc::clone(&produced);
        let mut n = 0u64;
        let pl = Pipeline::new(
            2,
            move || {
                n += 1;
                if n <= 10 {
                    p.store(n, Ordering::SeqCst);
                    Some(n)
                } else {
                    None
                }
            },
            |x| x,
        );
        // Let the pipeline fill its buffers before consuming anything.
        let first = pl.next().unwrap();
        assert_eq!(first, 1);
        for _ in 0..200 {
            if produced.load(Ordering::SeqCst) > 1 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert!(
            produced.load(Ordering::SeqCst) > 1,
            "stage 1 never ran ahead of the consumer"
        );
        while pl.next().is_some() {}
    }

    #[test]
    fn line_pump_streams_lines_then_eofs() {
        let (rx, h) = line_pump(Box::new(std::io::Cursor::new(b"a\nbb\nccc\n".to_vec())), 2);
        assert_eq!(rx.recv().unwrap(), "a");
        assert_eq!(rx.recv().unwrap(), "bb");
        assert_eq!(rx.recv().unwrap(), "ccc");
        assert!(rx.recv().is_err()); // EOF: pump exits, channel disconnects
        h.join().unwrap().unwrap();
    }

    #[test]
    fn line_pump_stops_when_consumer_drops() {
        // 10k lines through a depth-1 channel: the pump must exit on send
        // Err after the receiver is dropped, not write into the void.
        let big: String = (0..10_000).map(|i| format!("{i}\n")).collect();
        let (rx, h) = line_pump(Box::new(std::io::Cursor::new(big.into_bytes())), 1);
        assert_eq!(rx.recv().unwrap(), "0");
        drop(rx);
        h.join().unwrap().unwrap();
    }

    #[test]
    fn reduce_group_folds_in_rank_order_across_rounds() {
        // Reverse arrival order (higher ranks contribute first) must not
        // change the fold's view: contributions always arrive rank-ordered.
        let members = reduce_group(3, |vs: Vec<String>| vs.join("|"));
        let handles: Vec<_> = members
            .into_iter()
            .map(|m| {
                std::thread::spawn(move || {
                    std::thread::sleep(std::time::Duration::from_millis(
                        (3 - m.rank()) as u64 * 10,
                    ));
                    let mut out = Vec::new();
                    for round in 0..3 {
                        let r = m.reduce(format!("r{}s{round}", m.rank())).unwrap();
                        out.push((*r).clone());
                    }
                    out
                })
            })
            .collect();
        for h in handles {
            assert_eq!(
                h.join().unwrap(),
                vec!["r0s0|r1s0|r2s0", "r0s1|r1s1|r2s1", "r0s2|r1s2|r2s2"]
            );
        }
    }

    #[test]
    fn reduce_group_single_member_is_identity_loop() {
        let mut members = reduce_group(1, |vs: Vec<u64>| vs[0] * 2);
        let m = members.pop().unwrap();
        for i in 0..5u64 {
            assert_eq!(*m.reduce(i).unwrap(), i * 2);
        }
    }

    #[test]
    fn reduce_group_departed_member_unblocks_peers() {
        // Member 1 drops without ever contributing while member 0 is parked
        // in the barrier: member 0 must wake with Err, not deadlock, and all
        // later rounds must fail fast too.
        let mut members = reduce_group(2, |vs: Vec<u32>| vs.iter().sum::<u32>());
        let quitter = members.pop().unwrap();
        let m0 = members.pop().unwrap();
        let h = std::thread::spawn(move || m0.reduce(7).and_then(|_| m0.reduce(8)));
        std::thread::sleep(std::time::Duration::from_millis(20));
        drop(quitter);
        assert_eq!(h.join().unwrap(), Err(ReduceError));
    }

    #[test]
    fn reduce_group_departure_after_complete_round_fails_next() {
        // A full round completes; then one member unwinds. The survivor's
        // next round errors instead of waiting on a barrier that cannot fill.
        let mut members = reduce_group(2, |vs: Vec<u32>| vs.iter().sum::<u32>());
        let m1 = members.pop().unwrap();
        let m0 = members.pop().unwrap();
        let h = std::thread::spawn(move || {
            let first = m1.reduce(2).map(|r| *r);
            drop(m1); // simulates the replica's thread unwinding mid-stream
            first
        });
        assert_eq!(*m0.reduce(1).unwrap(), 3);
        assert_eq!(h.join().unwrap(), Ok(3));
        assert_eq!(m0.reduce(1), Err(ReduceError));
    }

    #[test]
    fn pipeline_drops_cleanly_mid_stream() {
        // Consumer drops with items still buffered: threads must exit (the
        // Drop of the JoinHandles would not hang the test binary).
        let mut n = 0u32;
        let pl = Pipeline::new(1, move || {
            n += 1;
            Some(n) // infinite producer
        }, |x| x);
        assert_eq!(pl.next(), Some(1));
        drop(pl);
    }
}
