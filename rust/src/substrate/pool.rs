//! Fixed-size thread pool + bounded prefetch channels (tokio is not in the
//! offline crate set; threads + std::sync::mpsc satisfy the coordinator's
//! needs: data prefetch, device encode, request-line pumping, and telemetry
//! I/O off the training hot path).
//!
//! This module is the repo's single home for spawned threads (the lint pass
//! of `rom analyze` enforces it; `std::thread::scope` elsewhere is fine —
//! scoped threads cannot leak). Every primitive here comes from
//! `substrate::sync`, the shim that swaps in loom's model-checked
//! `Mutex`/`Condvar`/`thread` under `RUSTFLAGS="--cfg loom"`; see
//! `tests/loom_pool.rs` for the exhaustive submit/join/drop interleaving
//! models of `ThreadPool`, `Prefetcher` and `Pipeline`.

use std::io::BufRead;

use crate::substrate::sync::mpsc::{sync_channel, Receiver, SyncSender};
use crate::substrate::sync::thread::JoinHandle;
use crate::substrate::sync::{thread, Arc, Condvar, Mutex};

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Job counter shared between submitters, workers and `join`: a mutex-guarded
/// count plus a condvar signaled when it reaches zero (no busy-wait).
struct InFlight {
    count: Mutex<usize>,
    all_done: Condvar,
}

impl InFlight {
    fn incr(&self) {
        *self.count.lock().unwrap() += 1;
    }

    fn decr(&self) {
        let mut n = self.count.lock().unwrap();
        *n -= 1;
        if *n == 0 {
            self.all_done.notify_all();
        }
    }

    fn wait_zero(&self) {
        let mut n = self.count.lock().unwrap();
        while *n != 0 {
            n = self.all_done.wait(n).unwrap();
        }
    }
}

/// Work-queue thread pool. Jobs run FIFO; `join` blocks until the queue
/// drains and all workers are idle.
pub struct ThreadPool {
    tx: Option<SyncSender<Job>>,
    workers: Vec<JoinHandle<()>>,
    in_flight: Arc<InFlight>,
}

impl ThreadPool {
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let (tx, rx) = sync_channel::<Job>(n * 4);
        let rx = Arc::new(Mutex::new(rx));
        let in_flight =
            Arc::new(InFlight { count: Mutex::new(0), all_done: Condvar::new() });
        let workers = (0..n)
            .map(|_| {
                let rx = Arc::clone(&rx);
                let in_flight = Arc::clone(&in_flight);
                thread::spawn(move || loop {
                    let job = {
                        let guard = rx.lock().unwrap();
                        guard.recv()
                    };
                    match job {
                        Ok(job) => {
                            job();
                            in_flight.decr();
                        }
                        Err(_) => break, // sender dropped: shut down
                    }
                })
            })
            .collect();
        ThreadPool { tx: Some(tx), workers, in_flight }
    }

    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.in_flight.incr();
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("worker panicked");
    }

    /// Block until all submitted jobs completed (condvar wait, not a spin).
    pub fn join(&self) {
        self.in_flight.wait_zero();
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.tx.take(); // close the channel; workers exit on recv Err
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Bounded single-producer prefetcher: a background thread runs `make()`
/// repeatedly and parks results in a channel of depth `depth`, overlapping
/// host-side batch assembly with device execution.
pub struct Prefetcher<T: Send + 'static> {
    rx: Receiver<T>,
    worker: Option<JoinHandle<()>>,
}

impl<T: Send + 'static> Prefetcher<T> {
    pub fn new<F>(depth: usize, mut make: F) -> Self
    where
        F: FnMut() -> Option<T> + Send + 'static,
    {
        let (tx, rx) = sync_channel(depth.max(1));
        let worker = thread::spawn(move || {
            while let Some(item) = make() {
                if tx.send(item).is_err() {
                    break; // consumer dropped
                }
            }
        });
        Prefetcher { rx, worker: Some(worker) }
    }

    /// Next prefetched item; None when the producer is exhausted.
    pub fn next(&self) -> Option<T> {
        self.rx.recv().ok()
    }
}

impl<T: Send + 'static> Drop for Prefetcher<T> {
    fn drop(&mut self) {
        // A producer blocked in `send` wakes with Err the moment `rx` above
        // goes away, so joining here cannot hang; it bounds the wait to at
        // most one in-progress `make()` and leaves no detached thread.
        let worker = self.worker.take();
        drop(std::mem::replace(&mut self.rx, sync_channel(1).1));
        if let Some(w) = worker {
            let _ = w.join();
        }
    }
}

/// Two-stage prefetch pipeline: stage 1 runs `make()` (e.g. window assembly),
/// stage 2 runs `convert()` on each item (e.g. `Tensor -> xla::Literal`
/// encode). Each stage owns a thread and a bounded channel of depth `depth`,
/// so with `depth >= 2` the pipeline is double-buffered: the consumer drains
/// device-ready items while assembly of batch k+1 and encode of batch k
/// proceed concurrently. Item order is preserved end to end (single thread
/// per stage, FIFO channels).
pub struct Pipeline<T: Send + 'static> {
    rx: Receiver<T>,
    stage1: Option<JoinHandle<()>>,
    stage2: Option<JoinHandle<()>>,
}

impl<T: Send + 'static> Pipeline<T> {
    pub fn new<U, F, G>(depth: usize, mut make: F, mut convert: G) -> Self
    where
        U: Send + 'static,
        F: FnMut() -> Option<U> + Send + 'static,
        G: FnMut(U) -> T + Send + 'static,
    {
        let depth = depth.max(1);
        let (tx1, rx1) = sync_channel::<U>(depth);
        let (tx2, rx2) = sync_channel::<T>(depth);
        let stage1 = thread::spawn(move || {
            while let Some(item) = make() {
                if tx1.send(item).is_err() {
                    break; // stage 2 gone: consumer dropped
                }
            }
        });
        let stage2 = thread::spawn(move || {
            while let Ok(item) = rx1.recv() {
                if tx2.send(convert(item)).is_err() {
                    break; // consumer dropped
                }
            }
        });
        Pipeline { rx: rx2, stage1: Some(stage1), stage2: Some(stage2) }
    }

    /// Next device-ready item; None when stage 1 is exhausted and the
    /// pipeline has drained.
    pub fn next(&self) -> Option<T> {
        self.rx.recv().ok()
    }
}

impl<T: Send + 'static> Drop for Pipeline<T> {
    fn drop(&mut self) {
        // Shutdown ordering: dropping the consumer end unblocks stage 2
        // (send Err), whose exit drops rx1 and unblocks stage 1 in turn —
        // so joining 2 then 1 always terminates, with no detached threads.
        let (s1, s2) = (self.stage1.take(), self.stage2.take());
        drop(std::mem::replace(&mut self.rx, sync_channel(1).1));
        if let Some(s) = s2 {
            let _ = s.join();
        }
        if let Some(s) = s1 {
            let _ = s.join();
        }
    }
}

/// Reader-thread line pump: stream lines from a reader over a bounded
/// channel, so a slow consumer backpressures the producer instead of
/// buffering unboundedly. This is the stdin/file request pump `rom serve`
/// uses; it lives here so every spawned thread in the crate stays inside
/// this module (the `rom analyze` lint enforces that confinement).
///
/// The pump stops at EOF or on the first I/O error (returned through the
/// handle); dropping the receiver stops it at the next line.
pub fn line_pump(
    source: Box<dyn BufRead + Send>,
    depth: usize,
) -> (Receiver<String>, JoinHandle<std::io::Result<()>>) {
    let (tx, rx) = sync_channel::<String>(depth.max(1));
    let handle = thread::spawn(move || -> std::io::Result<()> {
        for line in source.lines() {
            if tx.send(line?).is_err() {
                break; // pump gone — stop reading
            }
        }
        Ok(())
    });
    (rx, handle)
}

// Unit tests run real std threads, so they are meaningless (and would
// panic outside `loom::model`) in a `--cfg loom` build; the loom models
// live in tests/loom_pool.rs.
#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn pool_join_then_reuse() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        for round in 0..3 {
            for _ in 0..10 {
                let c = Arc::clone(&counter);
                pool.submit(move || {
                    c.fetch_add(1, Ordering::Relaxed);
                });
            }
            pool.join();
            assert_eq!(counter.load(Ordering::Relaxed), (round + 1) * 10);
        }
    }

    #[test]
    fn pool_join_waits_for_slow_jobs() {
        // join must actually block on the condvar until a deliberately slow
        // job finishes, not return early on an empty queue snapshot.
        let pool = ThreadPool::new(1);
        let counter = Arc::new(AtomicU64::new(0));
        let c = Arc::clone(&counter);
        pool.submit(move || {
            std::thread::sleep(std::time::Duration::from_millis(50));
            c.fetch_add(1, Ordering::Relaxed);
        });
        pool.join();
        assert_eq!(counter.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn pool_join_on_idle_pool_returns() {
        let pool = ThreadPool::new(2);
        pool.join(); // no jobs submitted: must not deadlock
    }

    #[test]
    fn prefetcher_yields_in_order_and_terminates() {
        let mut n = 0u32;
        let pf = Prefetcher::new(2, move || {
            n += 1;
            if n <= 5 {
                Some(n)
            } else {
                None
            }
        });
        let got: Vec<u32> = std::iter::from_fn(|| pf.next()).collect();
        assert_eq!(got, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn pipeline_preserves_order_and_terminates() {
        let mut n = 0u32;
        let pl = Pipeline::new(
            2,
            move || {
                n += 1;
                if n <= 20 {
                    Some(n)
                } else {
                    None
                }
            },
            |x| x * 10,
        );
        let got: Vec<u32> = std::iter::from_fn(|| pl.next()).collect();
        assert_eq!(got, (1..=20).map(|x| x * 10).collect::<Vec<_>>());
    }

    #[test]
    fn pipeline_stages_overlap() {
        // Stage 1 marks the highest item it has produced; by the time the
        // consumer sees item k, stage 1 must have run ahead of it (double
        // buffering), proving the stages are not in lockstep with the consumer.
        let produced = Arc::new(AtomicU64::new(0));
        let p = Arc::clone(&produced);
        let mut n = 0u64;
        let pl = Pipeline::new(
            2,
            move || {
                n += 1;
                if n <= 10 {
                    p.store(n, Ordering::SeqCst);
                    Some(n)
                } else {
                    None
                }
            },
            |x| x,
        );
        // Let the pipeline fill its buffers before consuming anything.
        let first = pl.next().unwrap();
        assert_eq!(first, 1);
        for _ in 0..200 {
            if produced.load(Ordering::SeqCst) > 1 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert!(
            produced.load(Ordering::SeqCst) > 1,
            "stage 1 never ran ahead of the consumer"
        );
        while pl.next().is_some() {}
    }

    #[test]
    fn line_pump_streams_lines_then_eofs() {
        let (rx, h) = line_pump(Box::new(std::io::Cursor::new(b"a\nbb\nccc\n".to_vec())), 2);
        assert_eq!(rx.recv().unwrap(), "a");
        assert_eq!(rx.recv().unwrap(), "bb");
        assert_eq!(rx.recv().unwrap(), "ccc");
        assert!(rx.recv().is_err()); // EOF: pump exits, channel disconnects
        h.join().unwrap().unwrap();
    }

    #[test]
    fn line_pump_stops_when_consumer_drops() {
        // 10k lines through a depth-1 channel: the pump must exit on send
        // Err after the receiver is dropped, not write into the void.
        let big: String = (0..10_000).map(|i| format!("{i}\n")).collect();
        let (rx, h) = line_pump(Box::new(std::io::Cursor::new(big.into_bytes())), 1);
        assert_eq!(rx.recv().unwrap(), "0");
        drop(rx);
        h.join().unwrap().unwrap();
    }

    #[test]
    fn pipeline_drops_cleanly_mid_stream() {
        // Consumer drops with items still buffered: threads must exit (the
        // Drop of the JoinHandles would not hang the test binary).
        let mut n = 0u32;
        let pl = Pipeline::new(1, move || {
            n += 1;
            Some(n) // infinite producer
        }, |x| x);
        assert_eq!(pl.next(), Some(1));
        drop(pl);
    }
}
