//! Fixed-size thread pool + bounded SPSC prefetch channel (tokio is not in
//! the offline crate set; threads + std::sync::mpsc satisfy the coordinator's
//! needs: data prefetch and telemetry I/O off the training hot path).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Work-queue thread pool. Jobs run FIFO; `join` blocks until the queue
/// drains and all workers are idle.
pub struct ThreadPool {
    tx: Option<SyncSender<Job>>,
    workers: Vec<JoinHandle<()>>,
    in_flight: Arc<AtomicUsize>,
}

impl ThreadPool {
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let (tx, rx) = sync_channel::<Job>(n * 4);
        let rx = Arc::new(Mutex::new(rx));
        let in_flight = Arc::new(AtomicUsize::new(0));
        let workers = (0..n)
            .map(|_| {
                let rx = Arc::clone(&rx);
                let in_flight = Arc::clone(&in_flight);
                std::thread::spawn(move || loop {
                    let job = {
                        let guard = rx.lock().unwrap();
                        guard.recv()
                    };
                    match job {
                        Ok(job) => {
                            job();
                            in_flight.fetch_sub(1, Ordering::Release);
                        }
                        Err(_) => break, // sender dropped: shut down
                    }
                })
            })
            .collect();
        ThreadPool { tx: Some(tx), workers, in_flight }
    }

    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.in_flight.fetch_add(1, Ordering::Acquire);
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("worker panicked");
    }

    /// Busy-wait (with yield) until all submitted jobs completed.
    pub fn join(&self) {
        while self.in_flight.load(Ordering::Acquire) != 0 {
            std::thread::yield_now();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.tx.take(); // close the channel; workers exit on recv Err
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Bounded single-producer prefetcher: a background thread runs `make()`
/// repeatedly and parks results in a channel of depth `depth`, overlapping
/// host-side batch assembly with device execution.
pub struct Prefetcher<T: Send + 'static> {
    rx: Receiver<T>,
    _worker: JoinHandle<()>,
}

impl<T: Send + 'static> Prefetcher<T> {
    pub fn new<F>(depth: usize, mut make: F) -> Self
    where
        F: FnMut() -> Option<T> + Send + 'static,
    {
        let (tx, rx) = sync_channel(depth.max(1));
        let worker = std::thread::spawn(move || {
            while let Some(item) = make() {
                if tx.send(item).is_err() {
                    break; // consumer dropped
                }
            }
        });
        Prefetcher { rx, _worker: worker }
    }

    /// Next prefetched item; None when the producer is exhausted.
    pub fn next(&self) -> Option<T> {
        self.rx.recv().ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn pool_join_then_reuse() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        for round in 0..3 {
            for _ in 0..10 {
                let c = Arc::clone(&counter);
                pool.submit(move || {
                    c.fetch_add(1, Ordering::Relaxed);
                });
            }
            pool.join();
            assert_eq!(counter.load(Ordering::Relaxed), (round + 1) * 10);
        }
    }

    #[test]
    fn prefetcher_yields_in_order_and_terminates() {
        let mut n = 0u32;
        let pf = Prefetcher::new(2, move || {
            n += 1;
            if n <= 5 {
                Some(n)
            } else {
                None
            }
        });
        let got: Vec<u32> = std::iter::from_fn(|| pf.next()).collect();
        assert_eq!(got, vec![1, 2, 3, 4, 5]);
    }
}
