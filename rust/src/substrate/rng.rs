//! Deterministic PRNG (splitmix64 + xoshiro256**) — rand crate is not in the
//! offline set, and the data pipeline needs stable, seedable, *splittable*
//! streams so shards are reproducible independent of thread scheduling.

/// xoshiro256** seeded via splitmix64. Passes BigCrush per the authors;
/// statistical sanity is unit-tested below.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // splitmix64 expansion of the seed into the 256-bit state.
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Derive an independent stream (jax-style fold_in): hash the pair.
    pub fn fold_in(&self, data: u64) -> Rng {
        let mix = self.s[0]
            ^ self.s[1].rotate_left(17)
            ^ data.wrapping_mul(0x9E3779B97F4A7C15);
        Rng::new(mix)
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, n) without modulo bias (Lemire rejection).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        loop {
            let x = self.next_u64();
            let (hi, lo) = mul_hi_lo(x, n);
            if lo >= n || lo >= x.wrapping_neg() % n {
                return hi;
            }
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut u = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u < 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

fn mul_hi_lo(a: u64, b: u64) -> (u64, u64) {
    let wide = (a as u128) * (b as u128);
    ((wide >> 64) as u64, wide as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn fold_in_is_independent() {
        let base = Rng::new(7);
        let mut a = base.fold_in(0);
        let mut b = base.fold_in(1);
        assert_ne!(a.next_u64(), b.next_u64());
        // and reproducible
        let mut a2 = Rng::new(7).fold_in(0);
        assert_eq!(Rng::new(7).fold_in(0).next_u64(), {
            let _ = &mut a2;
            a2.next_u64()
        });
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_unbiased_small_range() {
        let mut r = Rng::new(9);
        let mut counts = [0u32; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 500.0, "{counts:?}");
        }
    }

    #[test]
    fn weighted_follows_weights() {
        let mut r = Rng::new(11);
        let w = [1.0, 3.0];
        let mut c = [0u32; 2];
        for _ in 0..40_000 {
            c[r.weighted(&w)] += 1;
        }
        let frac = c[1] as f64 / 40_000.0;
        assert!((frac - 0.75).abs() < 0.02, "{frac}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }
}
