//! Data pipeline: synthetic corpus, tokenizer, sharded loader, probes.
pub mod corpus;
pub mod loader;
pub mod probes;
pub mod tokenizer;
