//! Downstream-task probes (the Table 2 stand-ins; DESIGN.md §3).
//!
//! Built from *held-out* corpus streams, both probes exercise the exact code
//! path the real benchmarks use (scored multiple-choice by per-option NLL):
//!
//!  * Cloze (LAMBADA-shape): predict the final token of a context window;
//!    candidates = the true token + 3 distractors sampled from other topics.
//!  * Continuation choice (HellaSwag-shape): given a prefix, pick the true
//!    `cont_len`-token continuation among 4 (3 shuffled/resampled).
//!
//! Scoring happens in coordinator::downstream using eval artifacts; this
//! module only *generates* the probe instances deterministically.

use crate::data::corpus::Corpus;
use crate::substrate::rng::Rng;

#[derive(Debug, Clone)]
pub struct ClozeInstance {
    /// Full window including the final (answer) position, length = ctx.
    pub context: Vec<i32>,
    /// 4 candidate final tokens; index 0 is NOT necessarily the answer.
    pub options: Vec<i32>,
    pub answer: usize,
}

#[derive(Debug, Clone)]
pub struct ContinuationInstance {
    pub prefix: Vec<i32>,
    /// 4 candidate continuations of equal length.
    pub options: Vec<Vec<i32>>,
    pub answer: usize,
}

pub fn make_cloze(corpus: &Corpus, seed: u64, n: usize, ctx: usize) -> Vec<ClozeInstance> {
    let mut rng = Rng::new(seed ^ 0xC102E);
    (0..n)
        .map(|i| {
            let stream = corpus.generate(0xDEAD_0000u64.wrapping_add(seed).wrapping_add(i as u64), ctx + 1);
            let context = stream[..ctx].to_vec();
            let truth = stream[ctx - 1 + 1]; // token after the window's last input
            let mut options = vec![truth];
            while options.len() < 4 {
                let cand = rng.below(corpus.spec().vocab as u64) as i32;
                if !options.contains(&cand) {
                    options.push(cand);
                }
            }
            rng.shuffle(&mut options);
            let answer = options.iter().position(|&o| o == truth).unwrap();
            ClozeInstance { context, options, answer }
        })
        .collect()
}

pub fn make_continuation(
    corpus: &Corpus,
    seed: u64,
    n: usize,
    prefix_len: usize,
    cont_len: usize,
) -> Vec<ContinuationInstance> {
    let mut rng = Rng::new(seed ^ 0x00C0117);
    (0..n)
        .map(|i| {
            let stream =
                corpus.generate(0xBEEF_0000u64.wrapping_add(seed).wrapping_add(i as u64), prefix_len + cont_len);
            let prefix = stream[..prefix_len].to_vec();
            let truth = stream[prefix_len..].to_vec();
            let mut options = vec![truth.clone()];
            for d in 0..3u64 {
                // Distractor: continuation drawn from an unrelated stream.
                let alt = corpus.generate(
                    0xFACE_0000u64
                        .wrapping_add(seed.wrapping_mul(31))
                        .wrapping_add(i as u64 * 7)
                        .wrapping_add(d),
                    cont_len,
                );
                options.push(alt);
            }
            rng.shuffle(&mut options);
            let answer = options.iter().position(|o| *o == truth).unwrap();
            ContinuationInstance { prefix, options, answer }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::CorpusSpec;

    fn corpus() -> Corpus {
        Corpus::new(CorpusSpec::default(), 1)
    }

    #[test]
    fn cloze_has_answer_among_options() {
        let c = corpus();
        for inst in make_cloze(&c, 0, 20, 32) {
            assert_eq!(inst.context.len(), 32);
            assert_eq!(inst.options.len(), 4);
            assert!(inst.answer < 4);
            let uniq: std::collections::HashSet<_> = inst.options.iter().collect();
            assert_eq!(uniq.len(), 4, "duplicate options");
        }
    }

    #[test]
    fn cloze_deterministic() {
        let c = corpus();
        let a = make_cloze(&c, 3, 5, 16);
        let b = make_cloze(&c, 3, 5, 16);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.context, y.context);
            assert_eq!(x.options, y.options);
            assert_eq!(x.answer, y.answer);
        }
    }

    #[test]
    fn continuation_options_equal_length_and_contain_truth() {
        let c = corpus();
        for inst in make_continuation(&c, 1, 10, 24, 8) {
            assert_eq!(inst.prefix.len(), 24);
            assert_eq!(inst.options.len(), 4);
            assert!(inst.options.iter().all(|o| o.len() == 8));
            assert!(inst.answer < 4);
        }
    }

    #[test]
    fn answers_are_spread() {
        // Shuffling must not leave the answer always at index 0.
        let c = corpus();
        let pos: Vec<usize> = make_cloze(&c, 5, 40, 16).iter().map(|i| i.answer).collect();
        assert!(pos.iter().any(|&p| p != pos[0]), "{pos:?}");
    }
}
