//! Sharded, deterministic batch loader over a token stream.
//!
//! The stream is cut into (seq_len + 1)-token windows; window order is
//! shuffled per epoch with a seeded RNG; shards partition windows disjointly
//! at batch-chunk granularity: the shuffled order is truncated to whole
//! `world * batch` groups (equal per-rank share — uneven shards would wedge
//! a barrier-style gradient reduction on the tail step) and batch-sized
//! chunk `c` goes to rank `c % world`. Concatenating every rank's step-`s`
//! chunk in rank order therefore reproduces exactly the step-`s` batch of a
//! world-1 loader with batch `world * batch` — the invariant the `--dp K`
//! bit-identity contract rests on. Targets are inputs shifted by one
//! (next-token prediction).

use crate::runtime::tensor::Tensor;
use crate::substrate::rng::Rng;
use crate::warnln;

#[derive(Debug, Clone)]
pub struct Batch {
    pub tokens: Tensor,  // i32 (B, T)
    pub targets: Tensor, // i32 (B, T)
}

/// One microbatch as borrowed row ranges into the parent `Batch` — the
/// grad-accum hot path encodes these straight to device literals, so copying
/// them into fresh `Tensor`s first would be pure overhead.
#[derive(Debug, Clone, Copy)]
pub struct MicroBatch<'a> {
    pub tokens: &'a [i32],  // (rows * seq_len) row-major
    pub targets: &'a [i32], // (rows * seq_len) row-major
    pub rows: usize,
    pub seq_len: usize,
}

impl<'a> MicroBatch<'a> {
    pub fn shape(&self) -> [usize; 2] {
        [self.rows, self.seq_len]
    }

    /// Materialize as owned tensors (slow path / tests).
    pub fn to_tensors(&self) -> (Tensor, Tensor) {
        (
            Tensor::i32(&self.shape(), self.tokens.to_vec()),
            Tensor::i32(&self.shape(), self.targets.to_vec()),
        )
    }
}

pub struct Loader {
    stream: Vec<i32>,
    seq_len: usize,
    batch: usize,
    world: usize,
    rank: usize,
    order: Vec<usize>,
    cursor: usize,
    epoch: u64,
    seed: u64,
}

impl Loader {
    pub fn new(stream: Vec<i32>, batch: usize, seq_len: usize, seed: u64) -> Loader {
        Loader::sharded(stream, batch, seq_len, seed, 1, 0)
    }

    pub fn sharded(
        stream: Vec<i32>,
        batch: usize,
        seq_len: usize,
        seed: u64,
        world: usize,
        rank: usize,
    ) -> Loader {
        assert!(rank < world);
        assert!(stream.len() > seq_len + 1, "stream shorter than one window");
        let num_windows = stream.len() / (seq_len + 1);
        // Every rank must own at least one batch-sized chunk per epoch
        // (`reshuffle` truncates the shuffled order to whole `world * batch`
        // groups); otherwise `next_batch` on the starved rank would reshuffle
        // forever into an empty order and index out of bounds. Fail loudly
        // at construction.
        assert!(
            num_windows >= world * batch.max(1),
            "world size {world} x batch {batch} exceeds {num_windows} windows \
             ({}-token stream, seq_len {seq_len}): rank {rank} would starve — \
             shrink world/batch or provide a longer stream",
            stream.len()
        );
        let mut l = Loader {
            stream,
            seq_len,
            batch,
            world,
            rank,
            order: Vec::new(),
            cursor: 0,
            epoch: 0,
            seed,
        };
        l.reshuffle();
        l
    }

    fn num_windows(&self) -> usize {
        self.stream.len() / (self.seq_len + 1)
    }

    fn reshuffle(&mut self) {
        let n = self.num_windows();
        let mut order: Vec<usize> = (0..n).collect();
        let mut rng = Rng::new(self.seed).fold_in(self.epoch);
        rng.shuffle(&mut order);
        // Equal-shard truncation: keep whole `world * batch` groups only, so
        // every rank draws exactly `usable / world` windows per epoch and all
        // ranks cross epoch boundaries on the same step. The remainder is
        // dropped from this epoch's *shuffled* order — different windows
        // fall off each epoch, so no window is permanently unreachable.
        let chunk = self.batch.max(1);
        let usable = n - n % (self.world * chunk);
        if usable < n && self.epoch == 0 && self.rank == 0 {
            warnln!(
                "loader drops {} of {n} windows per epoch to keep {} rank(s) of \
                 batch {chunk} in lockstep",
                n - usable,
                self.world
            );
        }
        order.truncate(usable);
        // Chunk round-robin: batch-sized chunk c of the shuffled order goes
        // to rank c % world, so rank-ordered concatenation of the per-step
        // chunks reproduces the world-1 (batch `world * chunk`) stream —
        // pinned by prop_dp_shards_concat_to_global_stream.
        self.order = order
            .chunks(chunk)
            .enumerate()
            .filter(|(c, _)| c % self.world == self.rank)
            .flat_map(|(_, ws)| ws.iter().copied())
            .collect();
        debug_assert!(
            !self.order.is_empty(),
            "rank {}/{} drew an empty shard from {} windows",
            self.rank,
            self.world,
            n
        );
        self.cursor = 0;
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Next (B, T) batch; rolls into the next epoch when exhausted.
    pub fn next_batch(&mut self) -> Batch {
        let t = self.seq_len;
        let mut tokens = Vec::with_capacity(self.batch * t);
        let mut targets = Vec::with_capacity(self.batch * t);
        for _ in 0..self.batch {
            if self.cursor >= self.order.len() {
                self.epoch += 1;
                self.reshuffle();
            }
            let w = self.order[self.cursor];
            self.cursor += 1;
            let start = w * (t + 1);
            let win = &self.stream[start..start + t + 1];
            tokens.extend_from_slice(&win[..t]);
            targets.extend_from_slice(&win[1..]);
        }
        Batch {
            tokens: Tensor::i32(&[self.batch, t], tokens),
            targets: Tensor::i32(&[self.batch, t], targets),
        }
    }

    /// Slice one batch into microbatches of `mb` rows (grad accumulation).
    /// Yields borrowed row ranges into `batch` — no payload copies.
    pub fn split_micro(batch: &Batch, mb: usize) -> Vec<MicroBatch<'_>> {
        let b = batch.tokens.shape[0];
        let t = batch.tokens.shape[1];
        assert!(b % mb == 0, "micro batch {mb} does not divide batch {b}");
        let tok = batch.tokens.as_i32().unwrap();
        let tgt = batch.targets.as_i32().unwrap();
        (0..b / mb)
            .map(|c| MicroBatch {
                tokens: &tok[c * mb * t..(c + 1) * mb * t],
                targets: &tgt[c * mb * t..(c + 1) * mb * t],
                rows: mb,
                seq_len: t,
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::proptest::{check, Config};

    fn stream(n: usize) -> Vec<i32> {
        (0..n as i32).collect()
    }

    #[test]
    fn targets_shift_by_one() {
        let mut l = Loader::new(stream(1000), 2, 8, 0);
        let b = l.next_batch();
        let tok = b.tokens.as_i32().unwrap();
        let tgt = b.targets.as_i32().unwrap();
        for i in 0..16 {
            assert_eq!(tgt[i], tok[i] + 1);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = Loader::new(stream(5000), 4, 16, 7);
        let mut b = Loader::new(stream(5000), 4, 16, 7);
        for _ in 0..5 {
            assert_eq!(
                a.next_batch().tokens.as_i32().unwrap(),
                b.next_batch().tokens.as_i32().unwrap()
            );
        }
    }

    #[test]
    fn epoch_rolls_and_reshuffles() {
        let mut l = Loader::new(stream(200), 2, 8, 1); // 22 windows
        let first: Vec<i32> = l.next_batch().tokens.as_i32().unwrap().to_vec();
        for _ in 0..20 {
            l.next_batch();
        }
        assert!(l.epoch() >= 1);
        // Order differs across epochs (seeded by epoch).
        let mut l2 = Loader::new(stream(200), 2, 8, 1);
        let e0: Vec<usize> = l2.order.clone();
        l2.epoch = 1;
        l2.reshuffle();
        assert_ne!(e0, l2.order);
        let _ = first;
    }

    #[test]
    fn prop_shards_partition_windows() {
        check("shard-partition", Config { cases: 24, seed: 3 }, |rng| {
            let world = 1 + rng.below(4) as usize;
            let t = 4 + rng.below(12) as usize;
            let whole = world * (2 + rng.below(6) as usize);
            let extra = rng.below(world as u64) as usize; // uneven remainder
            let s = stream((t + 1) * (whole + extra) + rng.below(t as u64) as usize);
            let num_windows = s.len() / (t + 1);
            let usable = num_windows - num_windows % world; // batch-1 chunks
            let mut seen = std::collections::HashSet::new();
            let mut total = 0usize;
            for rank in 0..world {
                let l = Loader::sharded(s.clone(), 1, t, 42, world, rank);
                // Equal per-rank share: a barrier-style reduction steps every
                // rank in lockstep, so no shard may run out a step early.
                crate::prop_assert_eq!(l.order.len(), usable / world);
                for &w in &l.order {
                    crate::prop_assert!(seen.insert(w), "window {w} in two shards");
                    total += 1;
                }
            }
            crate::prop_assert_eq!(total, usable);
            Ok(())
        });
    }

    #[test]
    fn prop_dp_shards_concat_to_global_stream() {
        // The --dp bit-identity contract at the data layer: at every step,
        // concatenating the world-K shard batches (shard batch B/K) in rank
        // order must equal the world-1 batch-B batch — same windows, same
        // row positions — including across epoch rollovers.
        check("dp-concat", Config { cases: 12, seed: 9 }, |rng| {
            let world = 2 + rng.below(3) as usize; // 2..=4 replicas
            let shard = 1 + rng.below(3) as usize; // rows per replica
            let t = 4 + rng.below(8) as usize;
            let b = world * shard;
            let windows = b * (2 + rng.below(4) as usize) + rng.below(b as u64) as usize;
            let s = stream((t + 1) * windows);
            let mut global = Loader::new(s.clone(), b, t, 11);
            let mut shards: Vec<Loader> = (0..world)
                .map(|r| Loader::sharded(s.clone(), shard, t, 11, world, r))
                .collect();
            for _ in 0..12 {
                let g = global.next_batch();
                let mut cat: Vec<i32> = Vec::new();
                for l in shards.iter_mut() {
                    cat.extend_from_slice(l.next_batch().tokens.as_i32().unwrap());
                }
                crate::prop_assert_eq!(cat, g.tokens.as_i32().unwrap().to_vec());
            }
            Ok(())
        });
    }

    #[test]
    fn prop_batches_never_ragged() {
        check("batch-shape", Config { cases: 16, seed: 4 }, |rng| {
            let b = 1 + rng.below(6) as usize;
            let t = 4 + rng.below(20) as usize;
            let mut l = Loader::new(stream((t + 1) * 10), b, t, rng.next_u64());
            for _ in 0..25 {
                let batch = l.next_batch();
                crate::prop_assert_eq!(batch.tokens.shape.clone(), vec![b, t]);
                crate::prop_assert_eq!(batch.targets.shape.clone(), vec![b, t]);
            }
            Ok(())
        });
    }

    #[test]
    fn split_micro_preserves_rows() {
        let mut l = Loader::new(stream(1000), 4, 8, 0);
        let b = l.next_batch();
        let micro = Loader::split_micro(&b, 2);
        assert_eq!(micro.len(), 2);
        for m in &micro {
            assert_eq!(m.shape(), [2, 8]);
            assert_eq!(m.tokens.len(), m.targets.len());
        }
        let all: Vec<i32> = micro.iter().flat_map(|m| m.tokens.to_vec()).collect();
        assert_eq!(all, b.tokens.as_i32().unwrap());
        // Borrowed views: same backing memory as the parent batch, no copy.
        assert_eq!(micro[0].tokens.as_ptr(), b.tokens.as_i32().unwrap().as_ptr());
        let (t0, g0) = micro[0].to_tensors();
        assert_eq!(t0.shape, vec![2, 8]);
        assert_eq!(g0.as_i32().unwrap(), micro[0].targets);
    }

    #[test]
    #[should_panic(expected = "would starve")]
    fn empty_shard_rejected_at_construction() {
        // 2 windows, world 3: rank 2 would never receive a window and the old
        // code hung/panicked deep inside next_batch. Must fail loudly instead.
        let s = stream(2 * 9 + 1); // seq_len 8 -> exactly 2 windows
        let _ = Loader::sharded(s, 1, 8, 0, 3, 2);
    }

    #[test]
    fn minimal_world_per_window_ok() {
        // world == num_windows is the boundary case: every rank gets exactly
        // one window and batches keep flowing across epoch rollovers.
        let s = stream(3 * 9); // 3 windows of seq_len 8
        for rank in 0..3 {
            let mut l = Loader::sharded(s.clone(), 1, 8, 5, 3, rank);
            for _ in 0..4 {
                let b = l.next_batch();
                assert_eq!(b.tokens.shape, vec![1, 8]);
            }
        }
    }
}
