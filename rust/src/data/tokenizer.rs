//! Byte-level tokenizer with a greedy merge table (BPE-lite) for ingesting
//! real text corpora as an alternative to the synthetic generator
//! (`rom train --corpus text --text-file ...`).
//!
//! Vocabulary layout: 0..=255 raw bytes, then merge tokens. Merges are
//! learned offline from a sample by counting adjacent pairs (the classic BPE
//! loop, greedy, no regex pre-splitting).

use std::collections::HashMap;

#[derive(Debug, Clone)]
pub struct Tokenizer {
    /// `merges[i]` = (left, right) producing token id 256 + i.
    merges: Vec<(i32, i32)>,
    rank: HashMap<(i32, i32), usize>,
}

impl Tokenizer {
    pub fn byte_level() -> Tokenizer {
        Tokenizer { merges: Vec::new(), rank: HashMap::new() }
    }

    /// Learn `n_merges` merges from sample text (greedy BPE).
    pub fn train(sample: &[u8], n_merges: usize) -> Tokenizer {
        let mut ids: Vec<i32> = sample.iter().map(|&b| b as i32).collect();
        let mut merges = Vec::with_capacity(n_merges);
        for m in 0..n_merges {
            let mut counts: HashMap<(i32, i32), usize> = HashMap::new();
            for w in ids.windows(2) {
                *counts.entry((w[0], w[1])).or_insert(0) += 1;
            }
            // Deterministic tie-break: highest count, then smallest pair.
            let Some((&pair, &cnt)) = counts
                .iter()
                .max_by_key(|(&(a, b), &c)| (c, std::cmp::Reverse((a, b))))
            else {
                break;
            };
            if cnt < 2 {
                break;
            }
            let new_id = 256 + m as i32;
            merges.push(pair);
            ids = merge_pass(&ids, pair, new_id);
        }
        let rank = merges
            .iter()
            .enumerate()
            .map(|(i, &p)| (p, i))
            .collect();
        Tokenizer { merges, rank }
    }

    pub fn vocab_size(&self) -> usize {
        256 + self.merges.len()
    }

    pub fn encode(&self, text: &[u8]) -> Vec<i32> {
        let mut ids: Vec<i32> = text.iter().map(|&b| b as i32).collect();
        // Apply merges in rank order until none applies (standard BPE encode).
        loop {
            let mut best: Option<(usize, usize)> = None; // (rank, position)
            for (i, w) in ids.windows(2).enumerate() {
                if let Some(&r) = self.rank.get(&(w[0], w[1])) {
                    if best.map_or(true, |(br, _)| r < br) {
                        best = Some((r, i));
                    }
                }
            }
            match best {
                Some((r, _)) => {
                    let pair = self.merges[r];
                    ids = merge_pass(&ids, pair, 256 + r as i32);
                }
                None => return ids,
            }
        }
    }

    pub fn decode(&self, ids: &[i32]) -> Vec<u8> {
        let mut out = Vec::new();
        for &id in ids {
            self.decode_one(id, &mut out);
        }
        out
    }

    fn decode_one(&self, id: i32, out: &mut Vec<u8>) {
        if id < 256 {
            out.push(id as u8);
        } else {
            let (l, r) = self.merges[(id - 256) as usize];
            self.decode_one(l, out);
            self.decode_one(r, out);
        }
    }
}

fn merge_pass(ids: &[i32], pair: (i32, i32), new_id: i32) -> Vec<i32> {
    let mut out = Vec::with_capacity(ids.len());
    let mut i = 0;
    while i < ids.len() {
        if i + 1 < ids.len() && (ids[i], ids[i + 1]) == pair {
            out.push(new_id);
            i += 2;
        } else {
            out.push(ids[i]);
            i += 1;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::proptest::{check, Config};
    use crate::substrate::rng::Rng;

    #[test]
    fn byte_level_roundtrip() {
        let t = Tokenizer::byte_level();
        let text = b"hello, mamba! \xf0\x9f\x90\x8d";
        assert_eq!(t.decode(&t.encode(text)), text.to_vec());
        assert_eq!(t.vocab_size(), 256);
    }

    #[test]
    fn training_learns_frequent_pairs() {
        let sample = b"the cat sat on the mat. the cat sat on the mat.".repeat(20);
        let t = Tokenizer::train(&sample, 16);
        assert!(t.merges.len() > 4);
        let enc = t.encode(&sample);
        assert!(enc.len() < sample.len() / 2, "compression too weak");
        assert_eq!(t.decode(&enc), sample);
    }

    #[test]
    fn encode_is_deterministic() {
        let sample = b"abababab cdcdcdcd".repeat(10);
        let t = Tokenizer::train(&sample, 8);
        assert_eq!(t.encode(b"abcd"), t.encode(b"abcd"));
    }

    #[test]
    fn prop_roundtrip_random_bytes() {
        let sample: Vec<u8> = (0..4000).map(|i| (i % 7 * 13 % 251) as u8).collect();
        let t = Tokenizer::train(&sample, 32);
        check("bpe-roundtrip", Config { cases: 24, seed: 6 }, |rng: &mut Rng| {
            let len = rng.below(200) as usize;
            let text: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
            let ids = t.encode(&text);
            crate::prop_assert!(
                ids.iter().all(|&i| (i as usize) < t.vocab_size()),
                "id out of range"
            );
            crate::prop_assert_eq!(t.decode(&ids), text);
            Ok(())
        });
    }
}
