//! Synthetic hierarchical-Markov corpus (the SlimPajama stand-in; DESIGN.md
//! §3 substitution table).
//!
//! Structure: a slow Markov chain over `n_topics` latent topics; each topic
//! owns a contiguous token cluster of `cluster` ids and an order-1 Markov
//! transition table over its cluster (sparse, seeded). 10% of emissions leak
//! into a *shared* vocabulary band so topics overlap (routers must work for
//! specialization, not get it for free from disjoint vocabularies).
//!
//! Why this preserves the paper-relevant behaviour:
//!   * per-topic transition tables give capacity-bound structure — bigger
//!     (total-parameter) models fit more tables, so RoM's sparse capacity
//!     shows up as lower PPL at equal active params (Fig 3 shape);
//!   * topic persistence creates long-range predictability — longer eval
//!     context lets a recurrent model hold the topic, so PPL improves with
//!     length (Fig 4 shape);
//!   * token clusters give the router a natural specialization signal
//!     (the paper's "cat -> expert 3" intuition, Fig 1).

use crate::substrate::rng::Rng;

#[derive(Debug, Clone)]
pub struct CorpusSpec {
    pub vocab: usize,
    pub n_topics: usize,
    pub cluster: usize,
    /// Expected topic run length (tokens).
    pub topic_persistence: f64,
    /// Probability of emitting from the shared band instead of the cluster.
    pub leak: f64,
    /// Markov concentration: higher = more deterministic transitions.
    pub sharpness: f64,
}

impl Default for CorpusSpec {
    fn default() -> Self {
        CorpusSpec {
            vocab: 512,
            n_topics: 8,
            cluster: 56, // 8*56 = 448 topic tokens + 64 shared band
            topic_persistence: 200.0,
            leak: 0.1,
            sharpness: 2.5,
        }
    }
}

/// The generator: seeded transition tables + a streaming sampler.
pub struct Corpus {
    spec: CorpusSpec,
    /// Per (topic, within) categorical over `cluster` successors,
    /// flattened: `trans[topic][within * cluster + next]`.
    trans: Vec<Vec<f64>>,
    shared_base: usize,
}

impl Corpus {
    pub fn new(spec: CorpusSpec, seed: u64) -> Corpus {
        assert!(spec.n_topics * spec.cluster <= spec.vocab);
        let shared_base = spec.n_topics * spec.cluster;
        let mut rng = Rng::new(seed ^ 0xC02B_0B5);
        let mut trans = Vec::with_capacity(spec.n_topics);
        for _t in 0..spec.n_topics {
            let mut table = vec![0.0f64; spec.cluster * spec.cluster];
            for row in 0..spec.cluster {
                for col in 0..spec.cluster {
                    // log-normal-ish weights sharpened: few likely successors.
                    let u = rng.next_f64();
                    table[row * spec.cluster + col] =
                        (-u.ln()).powf(spec.sharpness);
                }
            }
            trans.push(table);
        }
        Corpus { spec, trans, shared_base }
    }

    pub fn spec(&self) -> &CorpusSpec {
        &self.spec
    }

    /// Stream `len` tokens from an independent seeded stream.
    pub fn generate(&self, stream_seed: u64, len: usize) -> Vec<i32> {
        let mut rng = Rng::new(stream_seed ^ 0x5EED_DA7A);
        let spec = &self.spec;
        let mut topic = rng.below(spec.n_topics as u64) as usize;
        let mut within = rng.below(spec.cluster as u64) as usize;
        let switch_p = 1.0 / spec.topic_persistence;
        let shared_band = spec.vocab - self.shared_base;
        let mut out = Vec::with_capacity(len);
        for _ in 0..len {
            // Topic switching (slow chain).
            if rng.next_f64() < switch_p {
                topic = rng.below(spec.n_topics as u64) as usize;
                within = rng.below(spec.cluster as u64) as usize;
            }
            // Emit: cluster token (following the topic's Markov row) or leak
            // into the shared band.
            if shared_band > 0 && rng.next_f64() < spec.leak {
                out.push((self.shared_base + rng.below(shared_band as u64) as usize) as i32);
                // Shared emissions do not advance the within-topic state.
            } else {
                let row = &self.trans[topic]
                    [within * spec.cluster..(within + 1) * spec.cluster];
                within = rng.weighted(row);
                out.push((topic * spec.cluster + within) as i32);
            }
        }
        out
    }

    /// Topic of a token id (None for the shared band) — used by router
    /// specialization diagnostics.
    pub fn topic_of(&self, token: i32) -> Option<usize> {
        let t = token as usize;
        if t < self.shared_base {
            Some(t / self.spec.cluster)
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::proptest::{check, Config};

    #[test]
    fn deterministic_streams() {
        let c = Corpus::new(CorpusSpec::default(), 1);
        assert_eq!(c.generate(5, 1000), c.generate(5, 1000));
        assert_ne!(c.generate(5, 1000), c.generate(6, 1000));
    }

    #[test]
    fn tokens_in_range() {
        let c = Corpus::new(CorpusSpec::default(), 2);
        let toks = c.generate(0, 10_000);
        assert!(toks.iter().all(|&t| (0..512).contains(&t)));
    }

    #[test]
    fn topic_runs_are_persistent() {
        // Consecutive cluster tokens should usually share a topic.
        let c = Corpus::new(CorpusSpec::default(), 3);
        let toks = c.generate(1, 20_000);
        let topics: Vec<usize> = toks.iter().filter_map(|&t| c.topic_of(t)).collect();
        let same: usize = topics.windows(2).filter(|w| w[0] == w[1]).count();
        let frac = same as f64 / (topics.len() - 1) as f64;
        assert!(frac > 0.9, "topic persistence too low: {frac}");
    }

    #[test]
    fn all_topics_visited() {
        let c = Corpus::new(CorpusSpec::default(), 4);
        let toks = c.generate(2, 50_000);
        let mut seen = vec![false; 8];
        for &t in &toks {
            if let Some(tp) = c.topic_of(t) {
                seen[tp] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "{seen:?}");
    }

    #[test]
    fn transitions_are_structured_not_uniform() {
        // The bigram distribution within a topic must be far from uniform —
        // otherwise there is nothing for models to learn.
        let c = Corpus::new(CorpusSpec::default(), 5);
        let toks = c.generate(3, 100_000);
        let mut counts = std::collections::HashMap::new();
        for w in toks.windows(2) {
            if c.topic_of(w[0]) == Some(0) && c.topic_of(w[1]) == Some(0) {
                *counts.entry((w[0], w[1])).or_insert(0usize) += 1;
            }
        }
        let total: usize = counts.values().sum();
        let max = counts.values().copied().max().unwrap_or(0);
        // With 56 successors uniform would give max ~ total/56/56*hits...
        // just require strong concentration: some bigram takes >0.2% of mass
        // while uniform over 56^2 rows*cols would put 0.03% on each.
        assert!(max as f64 / total as f64 > 0.002, "{max}/{total}");
    }

    #[test]
    fn prop_spec_bounds_respected() {
        check("corpus-bounds", Config { cases: 16, seed: 9 }, |rng| {
            let spec = CorpusSpec {
                vocab: 128,
                n_topics: 1 + rng.below(4) as usize,
                cluster: 8 + rng.below(16) as usize,
                topic_persistence: 10.0 + rng.next_f64() * 100.0,
                leak: rng.next_f64() * 0.3,
                sharpness: 1.0 + rng.next_f64() * 3.0,
            };
            if spec.n_topics * spec.cluster > spec.vocab {
                return Ok(()); // invalid spec: constructor would assert
            }
            let c = Corpus::new(spec.clone(), rng.next_u64());
            let toks = c.generate(rng.next_u64(), 2000);
            crate::prop_assert!(
                toks.iter().all(|&t| (t as usize) < spec.vocab),
                "token out of range"
            );
            Ok(())
        });
    }
}
