//! Autoregressive generation: batched sampling over the prefill/decode
//! artifacts (`rom generate`).
//!
//! The sampling loop lives here, not in the artifact: the AOT programs only
//! know "state in, logits out", and the coordinator owns temperature/top-k
//! sampling over a seeded `substrate::rng` stream, prompt batching, and the
//! latency bookkeeping that `bench_generate` reports.
//!
//! Determinism contract: each prompt row samples from its own RNG stream,
//! `Rng::new(seed).fold_in(global_row_index)`, and every row's logits depend
//! only on that row's tokens (all artifact ops are per-row). Token output is
//! therefore a pure function of (checkpoint, prompt, seed, sampling params) —
//! independent of how prompts are chunked into device batches and of any
//! `--jobs`-style session parallelism around this call.
//!
//! Prompt handling is HYBRID: the longest `prefill_L{L}` artifact with
//! L <= prompt_len consumes the first L tokens in one chunk-parallel device
//! call, and only the remaining tail (if any) feeds through `decode_step`
//! one token at a time. Prompts shorter than every artifact length fall back
//! to the pure stepwise path (exact, just slower). Prompts must share one
//! length — batched decoding has no padding convention (padding would
//! corrupt the recurrent state).

use std::time::Instant;

use anyhow::{bail, Result};

use crate::runtime::session::Session;
use crate::runtime::tensor::Tensor;
use crate::substrate::rng::Rng;

/// Sampling parameters for one `generate` call.
#[derive(Debug, Clone)]
pub struct GenerateCfg {
    /// Tokens to generate per prompt (must be >= 1).
    pub max_new: usize,
    /// Softmax temperature; <= 0 selects greedy argmax decoding.
    pub temperature: f64,
    /// Restrict sampling to the k highest-probability tokens (0 = full
    /// vocabulary). Ignored under greedy decoding.
    pub top_k: usize,
    /// Base RNG seed; row `i` samples from `Rng::new(seed).fold_in(i)`.
    pub seed: u64,
}

impl Default for GenerateCfg {
    fn default() -> Self {
        GenerateCfg { max_new: 32, temperature: 0.0, top_k: 0, seed: 0 }
    }
}

/// Output of one `generate` call: the sampled continuations plus the latency
/// breakdown the generation bench records.
pub struct GenerateReport {
    /// One continuation (length `max_new`) per input prompt, in order.
    pub completions: Vec<Vec<i32>>,
    /// Shared prompt length.
    pub prompt_len: usize,
    /// Prompt tokens consumed through a `prefill_L{L}` artifact (per prompt):
    /// the longest L <= prompt_len, or 0 when every artifact is longer than
    /// the prompt and the stepwise fallback consumed it token by token. The
    /// remaining `prompt_len - prefill_artifact_tokens` tokens went through
    /// `decode_step`.
    pub prefill_artifact_tokens: usize,
    /// Total prompt-consumption wall time, summed over device batches.
    pub prefill_s: f64,
    /// Wall time of each decode_step device call during generation (each
    /// call advances every row of the device batch by one token).
    pub decode_step_s: Vec<f64>,
    /// Device batch rows (the artifact's baked-in decode batch).
    pub batch: usize,
    /// Real (non-padded) rows advanced across all timed decode steps — the
    /// numerator of the effective throughput. A short final chunk pads the
    /// device batch, and padded rows must not count as generated tokens.
    pub real_rows_stepped: usize,
}

impl GenerateReport {
    /// Median decode_step latency in milliseconds (None when generation
    /// needed no decode steps, i.e. max_new == 1). True median: even-length
    /// samples average the two middle elements.
    pub fn median_decode_ms(&self) -> Option<f64> {
        if self.decode_step_s.is_empty() {
            return None;
        }
        let mut v = self.decode_step_s.clone();
        v.sort_by(|a, b| a.partial_cmp(b).expect("non-NaN latency"));
        let n = v.len();
        let med = if n % 2 == 1 { v[n / 2] } else { (v[n / 2 - 1] + v[n / 2]) / 2.0 };
        Some(med * 1e3)
    }

    /// Effective decode throughput: real (non-padded) rows advanced per
    /// second of decode_step wall time — the tokens a caller actually
    /// receives. See `device_rows_per_sec` for the raw device rate.
    pub fn decode_tokens_per_sec(&self) -> Option<f64> {
        let total: f64 = self.decode_step_s.iter().sum();
        if total <= 0.0 {
            return None;
        }
        Some(self.real_rows_stepped as f64 / total)
    }

    /// Device decode throughput: ALL batch rows advanced per second of
    /// decode_step wall time, padded rows included — the artifact's rate,
    /// not per-prompt speed. Equals `decode_tokens_per_sec` only when the
    /// prompt count is a multiple of the decode batch.
    pub fn device_rows_per_sec(&self) -> Option<f64> {
        let total: f64 = self.decode_step_s.iter().sum();
        if total <= 0.0 {
            return None;
        }
        Some((self.batch * self.decode_step_s.len()) as f64 / total)
    }
}

/// Parse the CLI prompt grammar: comma-separated token ids, `;` between
/// prompts — `"1,2,3;4,5,6"` is two prompts of three tokens. One trailing
/// `;` (a common shell-quoting artifact) is tolerated; interior empty
/// prompts (`"1;;2"`) stay errors.
pub fn parse_prompt_tokens(s: &str) -> Result<Vec<Vec<i32>>> {
    let s = s.trim();
    let s = s.strip_suffix(';').unwrap_or(s);
    if s.trim().is_empty() {
        bail!("empty --prompt-tokens: expected comma-separated ids like 1,2,3");
    }
    let mut prompts = Vec::new();
    for (i, part) in s.split(';').enumerate() {
        if part.trim().is_empty() {
            bail!("empty prompt at position {i} in --prompt-tokens");
        }
        let mut prompt = Vec::new();
        for tok in part.split(',') {
            let tok = tok.trim();
            let id: i32 = tok
                .parse()
                .map_err(|_| anyhow::anyhow!("bad token id {tok:?} in prompt {i}"))?;
            prompt.push(id);
        }
        prompts.push(prompt);
    }
    Ok(prompts)
}

/// First index of the maximum (deterministic tie-break: lowest index).
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// The sampling state of ONE generated sequence: its RNG stream, sampling
/// params, emitted tokens and finish condition. `generate` owns one per real
/// prompt row for the life of a chunk; the serve engine keeps one resident
/// per batch slot and swaps it with the slot's state lanes — which is why
/// this is a first-class type rather than loop-local vectors.
#[derive(Debug, Clone)]
pub struct RowSampler {
    rng: Rng,
    pub temperature: f64,
    pub top_k: usize,
    /// Emission cap: `finished` turns true once this many tokens are out.
    pub max_new: usize,
    /// Optional stop token: emitted like any other draw, then the row is
    /// finished. `None` always runs to `max_new`.
    pub stop: Option<i32>,
    /// Tokens emitted so far, in order.
    pub emitted: Vec<i32>,
}

impl RowSampler {
    pub fn new(
        rng: Rng,
        temperature: f64,
        top_k: usize,
        max_new: usize,
        stop: Option<i32>,
    ) -> RowSampler {
        RowSampler { rng, temperature, top_k, max_new, stop, emitted: Vec::new() }
    }

    /// Draw the next token from a logits row, record and return it.
    pub fn sample(&mut self, logits: &[f32]) -> i32 {
        let tok = sample_token(logits, &mut self.rng, self.temperature, self.top_k) as i32;
        self.emitted.push(tok);
        tok
    }

    /// True once the row needs no more draws: `max_new` reached, or the
    /// last emitted token was the stop token.
    pub fn finished(&self) -> bool {
        self.emitted.len() >= self.max_new
            || self.stop.is_some_and(|s| self.emitted.last() == Some(&s))
    }
}

/// Sample one token id from a logits row. Temperature <= 0 is greedy; top_k
/// of 0 keeps the full vocabulary. Ties order by index, so the draw is a
/// deterministic function of (logits, rng state, params).
pub fn sample_token(logits: &[f32], rng: &mut Rng, temperature: f64, top_k: usize) -> usize {
    if temperature <= 0.0 {
        return argmax(logits);
    }
    let mut idx: Vec<usize> = (0..logits.len()).collect();
    if top_k > 0 && top_k < logits.len() {
        idx.sort_by(|&a, &b| {
            logits[b]
                .partial_cmp(&logits[a])
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        idx.truncate(top_k);
    }
    let max = idx.iter().map(|&i| logits[i] as f64).fold(f64::NEG_INFINITY, f64::max);
    let weights: Vec<f64> =
        idx.iter().map(|&i| ((logits[i] as f64 - max) / temperature).exp()).collect();
    idx[rng.weighted(&weights)]
}

/// Generate `cfg.max_new` tokens for every prompt. Prompts are chunked into
/// groups of the artifact's decode batch; a short final chunk pads with
/// copies of its first prompt (padded rows decode greedily and are
/// discarded — rows never interact, so padding cannot perturb real rows).
pub fn generate(
    sess: &Session,
    prompts: &[Vec<i32>],
    cfg: &GenerateCfg,
) -> Result<GenerateReport> {
    let man = &sess.bundle.manifest;
    let spec = sess.bundle.decode_spec()?;
    if prompts.is_empty() {
        bail!("no prompts given");
    }
    if cfg.max_new == 0 {
        bail!("--max-new must be >= 1 (got 0)");
    }
    let prompt_len = prompts[0].len();
    for (i, p) in prompts.iter().enumerate() {
        if p.is_empty() {
            bail!("empty prompt: prompt {i} has no tokens");
        }
        if p.len() != prompt_len {
            bail!(
                "ragged prompts: prompt {i} has {} tokens, prompt 0 has {prompt_len} \
                 (batched decoding requires equal prompt lengths)",
                p.len()
            );
        }
        if let Some(&t) = p.iter().find(|&&t| t < 0 || t as usize >= man.vocab_size) {
            bail!("prompt {i}: token {t} outside the vocabulary [0, {})", man.vocab_size);
        }
    }
    // Full-attention layouts have a hard cache capacity: consuming the prompt
    // writes slots 0..prompt_len-1 and the max_new-1 sampling steps write up
    // to slot prompt_len+max_new-2, so the whole request must fit under
    // kv_cap up front (the device scatter would silently clamp into the last
    // slot otherwise).
    if let Some(cap) = spec.kv_cap {
        let slots_needed = prompt_len + cfg.max_new - 1;
        if slots_needed > cap {
            bail!(
                "request exceeds the KV cache capacity: prompt_len {prompt_len} + \
                 max_new {} needs {slots_needed} cache slots but decode.kv_cap \
                 is {cap} — shorten the prompt or lower --max-new",
                cfg.max_new
            );
        }
    }

    let bd = spec.batch;
    let vocab = man.vocab_size;
    // Hybrid consumption: the longest artifact prefix that fits the prompt.
    let artifact_len = spec.prefill_lens.iter().copied().filter(|&l| l <= prompt_len).max();
    let mut completions: Vec<Vec<i32>> = Vec::with_capacity(prompts.len());
    let mut prefill_s = 0.0f64;
    let mut decode_step_s: Vec<f64> = Vec::new();
    let mut real_rows_stepped = 0usize;

    for chunk in prompts.chunks(bd) {
        // Pad the device batch with copies of the chunk's first prompt.
        let rows: Vec<&Vec<i32>> =
            (0..bd).map(|r| chunk.get(r).unwrap_or(&chunk[0])).collect();
        let row_base = completions.len(); // global index of this chunk's row 0
        let mut samplers: Vec<RowSampler> = (0..chunk.len())
            .map(|r| {
                RowSampler::new(
                    Rng::new(cfg.seed).fold_in((row_base + r) as u64),
                    cfg.temperature,
                    cfg.top_k,
                    cfg.max_new,
                    None,
                )
            })
            .collect();

        // Consume the prompt: the longest-matching artifact prefix in one
        // chunk-parallel device call, then stepwise for the tail (the whole
        // prompt when no artifact fits).
        let t0 = Instant::now();
        let (mut logits, mut state) = match artifact_len {
            Some(l) => {
                let mut flat = Vec::with_capacity(bd * l);
                for row in &rows {
                    flat.extend_from_slice(&row[..l]);
                }
                sess.prefill(&Tensor::i32(&[bd, l], flat))?
            }
            None => {
                let state = sess.init_decode_state()?;
                let toks: Vec<i32> = rows.iter().map(|r| r[0]).collect();
                let mut state = state;
                let logits = sess.decode_step(&Tensor::i32(&[bd], toks), &mut state)?;
                (logits, state)
            }
        };
        for t in artifact_len.unwrap_or(1)..prompt_len {
            let toks: Vec<i32> = rows.iter().map(|r| r[t]).collect();
            logits = sess.decode_step(&Tensor::i32(&[bd], toks), &mut state)?;
        }
        prefill_s += t0.elapsed().as_secs_f64();

        // Sampling loop: draw from the current logits, then advance the
        // state only while more tokens are needed.
        for step_i in 0..cfg.max_new {
            let lv = logits.as_f32()?;
            if lv.len() != bd * vocab {
                bail!("decode logits: {} values, expected {}", lv.len(), bd * vocab);
            }
            let mut next: Vec<i32> = Vec::with_capacity(bd);
            for r in 0..bd {
                let row_logits = &lv[r * vocab..(r + 1) * vocab];
                let tok = if r < chunk.len() {
                    samplers[r].sample(row_logits)
                } else {
                    argmax(row_logits) as i32 // padded row: deterministic fill
                };
                next.push(tok);
            }
            if step_i + 1 < cfg.max_new {
                let t1 = Instant::now();
                logits = sess.decode_step(&Tensor::i32(&[bd], next), &mut state)?;
                decode_step_s.push(t1.elapsed().as_secs_f64());
                real_rows_stepped += chunk.len();
            }
        }
        completions.extend(samplers.into_iter().map(|s| s.emitted));
    }

    Ok(GenerateReport {
        completions,
        prompt_len,
        prefill_artifact_tokens: artifact_len.unwrap_or(0),
        prefill_s,
        decode_step_s,
        batch: bd,
        real_rows_stepped,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_prompts_grammar() {
        let p = parse_prompt_tokens("1,2,3;4, 5 ,6").unwrap();
        assert_eq!(p, vec![vec![1, 2, 3], vec![4, 5, 6]]);
        assert_eq!(parse_prompt_tokens("7").unwrap(), vec![vec![7]]);
        assert!(parse_prompt_tokens("").is_err());
        assert!(parse_prompt_tokens("1,2;;3").is_err());
        assert!(parse_prompt_tokens("1,x,3").is_err());
    }

    #[test]
    fn parse_prompts_tolerates_trailing_semicolon() {
        // `rom generate --prompt-tokens '1,2,3;'` — a shell artifact, not an
        // empty prompt.
        assert_eq!(parse_prompt_tokens("1,2,3;").unwrap(), vec![vec![1, 2, 3]]);
        assert_eq!(
            parse_prompt_tokens(" 1,2;3,4; ").unwrap(),
            vec![vec![1, 2], vec![3, 4]]
        );
        // Only ONE trailing separator is forgiven; doubled is still a typo.
        assert!(parse_prompt_tokens("1,2;;").is_err());
        assert!(parse_prompt_tokens(";").is_err());
    }

    fn report_with(batch: usize, decode_step_s: Vec<f64>, real_rows: usize) -> GenerateReport {
        GenerateReport {
            completions: Vec::new(),
            prompt_len: 4,
            prefill_artifact_tokens: 4,
            prefill_s: 0.0,
            decode_step_s,
            batch,
            real_rows_stepped: real_rows,
        }
    }

    #[test]
    fn median_decode_is_a_true_median() {
        // Odd count: the middle element.
        let r = report_with(1, vec![0.003, 0.001, 0.002], 3);
        assert_eq!(r.median_decode_ms(), Some(2.0));
        // Even count: MEAN of the two middle elements, not the upper one.
        let r = report_with(1, vec![0.004, 0.001, 0.003, 0.002], 4);
        assert_eq!(r.median_decode_ms(), Some(2.5));
        assert_eq!(report_with(1, vec![], 0).median_decode_ms(), None);
    }

    #[test]
    fn padded_rows_do_not_inflate_throughput() {
        // One real prompt in a 4-row device batch, 5 timed steps of 10ms:
        // the device advances 20 rows but only 5 tokens reach a caller.
        let r = report_with(4, vec![0.01; 5], 5);
        let effective = r.decode_tokens_per_sec().unwrap();
        let device = r.device_rows_per_sec().unwrap();
        assert!((effective - 100.0).abs() < 1e-9, "effective {effective}");
        assert!((device - 400.0).abs() < 1e-9, "device {device}");
        // Full batch: the two rates agree.
        let full = report_with(4, vec![0.01; 5], 20);
        assert_eq!(
            full.decode_tokens_per_sec().unwrap(),
            full.device_rows_per_sec().unwrap()
        );
    }

    #[test]
    fn row_sampler_matches_raw_stream_and_finishes() {
        let logits: Vec<f32> = (0..16).map(|i| ((i * 13) % 7) as f32 * 0.4).collect();
        // The sampler's draws are exactly the raw sample_token stream on the
        // same RNG (slot-residency must not change the tokens).
        let mut raw_rng = Rng::new(9).fold_in(0);
        let mut s = RowSampler::new(Rng::new(9).fold_in(0), 1.2, 4, 3, None);
        for _ in 0..3 {
            let want = sample_token(&logits, &mut raw_rng, 1.2, 4) as i32;
            assert!(!s.finished());
            assert_eq!(s.sample(&logits), want);
        }
        assert!(s.finished(), "max_new reached");
        assert_eq!(s.emitted.len(), 3);

        // Stop token: emitted, then finished early.
        let mut s = RowSampler::new(Rng::new(0), 0.0, 0, 10, Some(argmax(&logits) as i32));
        s.sample(&logits);
        assert!(s.finished(), "stop token finishes the row");
        assert_eq!(s.emitted.len(), 1);
    }

    #[test]
    fn argmax_breaks_ties_low() {
        assert_eq!(argmax(&[0.0, 3.0, 3.0, 1.0]), 1);
        assert_eq!(argmax(&[5.0]), 0);
    }

    #[test]
    fn greedy_ignores_rng_and_topk() {
        let logits = [0.1, 2.0, -1.0, 1.9];
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_eq!(sample_token(&logits, &mut a, 0.0, 0), 1);
        assert_eq!(sample_token(&logits, &mut b, 0.0, 3), 1);
    }

    #[test]
    fn top_k_restricts_support() {
        let logits = [0.0, 5.0, 4.0, -2.0, 1.0];
        let mut rng = Rng::new(7);
        for _ in 0..200 {
            let t = sample_token(&logits, &mut rng, 2.0, 2);
            assert!(t == 1 || t == 2, "token {t} outside top-2");
        }
        // top_k = 1 degenerates to argmax whatever the temperature.
        assert_eq!(sample_token(&logits, &mut rng, 10.0, 1), 1);
    }

    #[test]
    fn sampling_is_seed_deterministic() {
        let logits: Vec<f32> = (0..32).map(|i| ((i * 37) % 11) as f32 * 0.3).collect();
        let draw = |seed: u64| -> Vec<usize> {
            let mut rng = Rng::new(seed).fold_in(0);
            (0..16).map(|_| sample_token(&logits, &mut rng, 0.8, 4)).collect()
        };
        assert_eq!(draw(42), draw(42));
        assert_ne!(draw(42), draw(43)); // astronomically unlikely to collide
    }

    #[test]
    fn temperature_sharpens_distribution() {
        let logits = [0.0, 1.0];
        let count_top = |temp: f64| -> usize {
            let mut rng = Rng::new(3);
            (0..2000).filter(|_| sample_token(&logits, &mut rng, temp, 0) == 1).count()
        };
        let cold = count_top(0.25);
        let hot = count_top(4.0);
        assert!(cold > hot, "T=0.25 picked top {cold} vs T=4.0 {hot}");
        assert!(cold > 1900, "near-greedy at low temperature: {cold}");
        assert!(hot > 800 && hot < 1500, "near-uniform at high temperature: {hot}");
    }
}
