//! Downstream probe scoring (Table 2 stand-in): rank multiple-choice options
//! by model NLL using the eval artifacts.
//!
//! Cloze (LAMBADA-shape) uses the eval_last artifact (final-position NLL);
//! continuation choice (HellaSwag-shape) uses full-sequence NLL — prefix
//! positions contribute identically to every option, so ranking by total NLL
//! equals ranking by continuation NLL.

use anyhow::{bail, Result};

use crate::data::probes::{ClozeInstance, ContinuationInstance};
use crate::runtime::session::Session;
use crate::runtime::tensor::Tensor;

#[derive(Debug, Clone, Default)]
pub struct ProbeResult {
    pub accuracy: f64,
    /// Mean NLL of the *true* option (the LAMBADA-PPL analogue for cloze).
    pub true_nll: f64,
    pub n: usize,
}

impl ProbeResult {
    pub fn ppl(&self) -> f64 {
        self.true_nll.exp()
    }
}

/// Score cloze instances: every option substitutes the final target.
pub fn score_cloze(sess: &Session, instances: &[ClozeInstance]) -> Result<ProbeResult> {
    if instances.is_empty() {
        bail!("no cloze instances");
    }
    let ctx = instances[0].context.len();
    let mut correct = 0usize;
    let mut true_nll = 0.0;
    for inst in instances {
        let tokens = Tensor::i32(&[1, ctx], inst.context.clone());
        let mut best = (f64::INFINITY, 0usize);
        for (oi, &opt) in inst.options.iter().enumerate() {
            // Targets: shifted context with the final target = option. Only
            // the last position is scored by eval_last.
            let mut tgt: Vec<i32> = inst.context[1..].to_vec();
            tgt.push(opt);
            let targets = Tensor::i32(&[1, ctx], tgt);
            let (nll, _) = sess.eval_last(ctx, &tokens, &targets)?;
            if oi == inst.answer {
                true_nll += nll;
            }
            if nll < best.0 {
                best = (nll, oi);
            }
        }
        if best.1 == inst.answer {
            correct += 1;
        }
    }
    Ok(ProbeResult {
        accuracy: correct as f64 / instances.len() as f64,
        true_nll: true_nll / instances.len() as f64,
        n: instances.len(),
    })
}

/// Score continuation choices with full-sequence NLL at a fixed length.
pub fn score_continuation(
    sess: &Session,
    instances: &[ContinuationInstance],
) -> Result<ProbeResult> {
    if instances.is_empty() {
        bail!("no continuation instances");
    }
    let total = instances[0].prefix.len() + instances[0].options[0].len();
    let mut correct = 0usize;
    let mut true_nll = 0.0;
    for inst in instances {
        let mut best = (f64::INFINITY, 0usize);
        for (oi, opt) in inst.options.iter().enumerate() {
            let mut seq = inst.prefix.clone();
            seq.extend_from_slice(opt);
            debug_assert_eq!(seq.len(), total);
            let tokens = Tensor::i32(&[1, total], seq[..total].to_vec());
            let mut tgt = seq[1..].to_vec();
            tgt.push(0);
            let targets = Tensor::i32(&[1, total], tgt);
            let (nll, count) = sess.eval(total, &tokens, &targets)?;
            let per_tok = nll / count;
            if oi == inst.answer {
                true_nll += per_tok;
            }
            if per_tok < best.0 {
                best = (per_tok, oi);
            }
        }
        if best.1 == inst.answer {
            correct += 1;
        }
    }
    Ok(ProbeResult {
        accuracy: correct as f64 / instances.len() as f64,
        true_nll: true_nll / instances.len() as f64,
        n: instances.len(),
    })
}
