//! Training telemetry: loss curve, throughput meter, JSON export.
//!
//! Everything here is allocation-light on the hot path (fixed-capacity ring
//! for the throughput meter, plain Vec pushes for curves) and is drained by
//! the background telemetry thread, not the step loop.

use std::time::Instant;

use crate::substrate::json::Json;

#[derive(Debug, Clone)]
pub struct LossPoint {
    pub step: u64,
    pub loss: f64,
    pub lr: f64,
    pub tokens_seen: u64,
}

/// Sliding-window tokens/second meter.
pub struct Throughput {
    window: Vec<(Instant, u64)>, // (time, cumulative tokens)
    cap: usize,
    total_tokens: u64,
    start: Instant,
}

impl Throughput {
    pub fn new() -> Throughput {
        Throughput { window: Vec::new(), cap: 50, total_tokens: 0, start: Instant::now() }
    }

    pub fn record(&mut self, tokens: u64) {
        self.total_tokens += tokens;
        self.window.push((Instant::now(), self.total_tokens));
        if self.window.len() > self.cap {
            self.window.remove(0);
        }
    }

    /// Tokens/s over the sliding window (None until 2 samples).
    pub fn rate(&self) -> Option<f64> {
        let (t0, c0) = *self.window.first()?;
        let (t1, c1) = *self.window.last()?;
        let dt = (t1 - t0).as_secs_f64();
        if dt <= 0.0 || c1 == c0 {
            return None;
        }
        Some((c1 - c0) as f64 / dt)
    }

    pub fn total_tokens(&self) -> u64 {
        self.total_tokens
    }

    pub fn overall_rate(&self) -> f64 {
        self.total_tokens as f64 / self.start.elapsed().as_secs_f64().max(1e-9)
    }
}

impl Default for Throughput {
    fn default() -> Self {
        Self::new()
    }
}

/// Run-level metric sink.
#[derive(Default)]
pub struct Metrics {
    pub losses: Vec<LossPoint>,
    pub evals: Vec<(u64, usize, f64)>, // (step, ctx_len, ppl)
}

impl Metrics {
    pub fn log_loss(&mut self, step: u64, loss: f64, lr: f64, tokens_seen: u64) {
        self.losses.push(LossPoint { step, loss, lr, tokens_seen });
    }

    pub fn log_eval(&mut self, step: u64, ctx: usize, ppl: f64) {
        self.evals.push((step, ctx, ppl));
    }

    pub fn last_loss(&self) -> Option<f64> {
        self.losses.last().map(|p| p.loss)
    }

    /// Mean loss over the last `n` points (smoothed readout for tables).
    pub fn smoothed_loss(&self, n: usize) -> Option<f64> {
        if self.losses.is_empty() {
            return None;
        }
        let tail = &self.losses[self.losses.len().saturating_sub(n)..];
        Some(tail.iter().map(|p| p.loss).sum::<f64>() / tail.len() as f64)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "losses",
                Json::Arr(
                    self.losses
                        .iter()
                        .map(|p| {
                            Json::obj(vec![
                                ("step", Json::num(p.step as f64)),
                                ("loss", Json::num(p.loss)),
                                ("lr", Json::num(p.lr)),
                                ("tokens", Json::num(p.tokens_seen as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "evals",
                Json::Arr(
                    self.evals
                        .iter()
                        .map(|(s, c, p)| {
                            Json::obj(vec![
                                ("step", Json::num(*s as f64)),
                                ("ctx", Json::num(*c as f64)),
                                ("ppl", Json::num(*p)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn save(&self, path: &std::path::Path) -> anyhow::Result<()> {
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoothed_loss_window() {
        let mut m = Metrics::default();
        for (i, l) in [10.0, 8.0, 6.0, 4.0].iter().enumerate() {
            m.log_loss(i as u64, *l, 1e-3, 0);
        }
        assert_eq!(m.smoothed_loss(2), Some(5.0));
        assert_eq!(m.last_loss(), Some(4.0));
        assert_eq!(m.smoothed_loss(100), Some(7.0));
    }

    #[test]
    fn throughput_counts_tokens() {
        let mut t = Throughput::new();
        t.record(100);
        std::thread::sleep(std::time::Duration::from_millis(5));
        t.record(100);
        assert_eq!(t.total_tokens(), 200);
        assert!(t.rate().unwrap() > 0.0);
        assert!(t.overall_rate() > 0.0);
    }

    #[test]
    fn json_export_parses_back() {
        let mut m = Metrics::default();
        m.log_loss(1, 5.0, 1e-3, 2048);
        m.log_eval(1, 128, 12.5);
        let j = m.to_json();
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("losses").unwrap().as_arr().unwrap().len(), 1);
        assert_eq!(parsed.get("evals").unwrap().as_arr().unwrap().len(), 1);
    }
}
