//! Continuous-batching generation service: the long-lived request loop
//! behind `rom serve`.
//!
//! The decode artifacts bake a fixed device batch of `decode_spec().batch`
//! rows, and SSM decode state is fixed-size per sequence — so serving is
//! slot scheduling: the engine keeps one live batched `DecodeState`, treats
//! each batch row as a slot, and when a sequence finishes (max_new reached
//! or stop token sampled) it swaps the next queued prompt into the freed
//! slot's state lanes (`Session::inject_state_row`) without disturbing the
//! other rows. Prompt consumption is HYBRID, exactly as in `generate`: the
//! longest `prefill_L{L}` artifact with L <= prompt_len consumes the prefix
//! in one chunk-parallel device call and only the tail goes through stepwise
//! decode_step (the whole prompt when no artifact fits) — and because
//! admission is per-slot, requests of DIFFERENT prompt lengths coexist in
//! one batch (the equal-length restriction of `generate` holds only within
//! one device call, not across the request stream).
//!
//! Determinism contract: a request samples from `Rng::new(seed).fold_in(0)`
//! and its row's logits depend only on its own tokens (all artifact ops are
//! per-row), so each response is bit-identical to a standalone
//! `rom generate` run with the same checkpoint, prompt, seed and sampling
//! params — regardless of which slot it landed in, what its neighbors were
//! doing, or how admissions interleaved. One exception is structural:
//! layouts with SWA blocks read the shared `pos` state scalar (RoPE +
//! cache-validity masking), so their rows cannot sit at different sequence
//! positions in one batch. For those the engine degrades to gang admission
//! (`DecodeSpec::position_dependent`): it waits until every slot is free,
//! admits a FIFO run of equal-length prompts on a fresh state, and swaps
//! nothing in mid-stream. Pure-SSM layouts get full continuous batching.
//!
//! Full-attention layouts (window <= 0) additionally carry a capped KV
//! cache of `decode.kv_cap` absolute positions. The engine never steps
//! past the cap: a prompt longer than the cap is rejected at `submit`,
//! and a request whose generation reaches the cap mid-stream is retired
//! cleanly with `FinishReason::KvCapExhausted` — never a panic, and never
//! a silently-clamped cache write.
//!
//! The engine is deliberately single-threaded and pull-based: `submit`
//! enqueues (bounded, with backpressure), `step` advances the world by at
//! most one batched decode call, and the caller owns the loop — the CLI
//! pumps it against a stdin reader thread, tests drive it deterministically,
//! and the session never has to cross a thread boundary.

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::coordinator::generate::{parse_prompt_tokens, RowSampler};
use crate::runtime::session::{DecodeState, Session};
use crate::runtime::tensor::Tensor;
use crate::substrate::rng::Rng;

/// Engine-level configuration (per-request knobs live on `Request`).
#[derive(Debug, Clone)]
pub struct ServeCfg {
    /// Admission-queue bound: `submit` rejects (returns the request to the
    /// caller) once this many requests are waiting for a slot.
    pub queue_cap: usize,
}

impl Default for ServeCfg {
    fn default() -> Self {
        ServeCfg { queue_cap: 64 }
    }
}

/// One generation request: a prompt plus its own sampling params — every
/// request on the loop can use a different temperature/seed/stop condition.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub prompt: Vec<i32>,
    /// Tokens to generate (>= 1); generation may end earlier on `stop`.
    pub max_new: usize,
    /// Softmax temperature; <= 0 selects greedy argmax decoding.
    pub temperature: f64,
    /// Restrict sampling to the k highest-probability tokens (0 = full
    /// vocabulary). Ignored under greedy decoding.
    pub top_k: usize,
    /// RNG seed; the request samples from `Rng::new(seed).fold_in(0)` — the
    /// stream a single-prompt `rom generate --seed` run uses.
    pub seed: u64,
    /// Optional stop token: emitted like any other draw, then the request
    /// finishes early.
    pub stop: Option<i32>,
}

impl Default for Request {
    fn default() -> Self {
        Request {
            prompt: Vec::new(),
            max_new: 32,
            temperature: 0.0,
            top_k: 0,
            seed: 0,
            stop: None,
        }
    }
}

/// Why a request finished.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    /// Ran to its `max_new` emission cap.
    MaxNew,
    /// Sampled its stop token (included in the output).
    Stop,
    /// The layout's KV cache (`decode.kv_cap` slots for full-attention
    /// blocks) ran out of positions before `max_new` tokens were emitted.
    /// The request keeps everything sampled so far; stepping past the cap
    /// is never attempted (XLA would silently clamp the scatter index and
    /// corrupt the last cache slot).
    KvCapExhausted,
}

/// One completed request with its latency breakdown.
#[derive(Debug, Clone)]
pub struct Response {
    /// Admission id handed back by `submit`, in submission order.
    pub id: u64,
    pub prompt: Vec<i32>,
    /// Sampled continuation (stop token included when `finish == Stop`).
    pub tokens: Vec<i32>,
    pub finish: FinishReason,
    /// Prompt tokens consumed through a `prefill_L{L}` artifact: the longest
    /// L <= prompt length (0 = the stepwise fallback consumed everything).
    /// The remaining prompt tokens went through decode_step — per-request,
    /// so serve stats stay honest under hybrid consumption.
    pub prefill_artifact_tokens: usize,
    /// Submission -> slot admission (time spent queued behind other work).
    pub queue_wait_s: f64,
    /// Submission -> first token sampled (queue wait + prompt consumption).
    pub ttft_s: f64,
    /// Wall time of each batched decode step this request rode on — its
    /// per-token inter-arrival latencies after the first token.
    pub token_s: Vec<f64>,
}

/// Outcome of `submit`: accepted into the queue, or bounced by backpressure
/// with the request handed back intact so the caller can retry later.
#[derive(Debug)]
pub enum Submit {
    Accepted(u64),
    Rejected(Request),
}

/// Robust summary of one latency distribution, in milliseconds.
#[derive(Debug, Clone)]
pub struct LatencyStats {
    pub count: usize,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p90_ms: f64,
    pub p99_ms: f64,
    pub max_ms: f64,
}

impl LatencyStats {
    /// Summarize samples given in seconds (None when empty).
    pub fn from_secs(samples: &[f64]) -> Option<LatencyStats> {
        if samples.is_empty() {
            return None;
        }
        let mut v: Vec<f64> = samples.iter().map(|s| s * 1e3).collect();
        v.sort_by(|a, b| a.partial_cmp(b).expect("non-NaN latency"));
        let q = |p: f64| v[((v.len() - 1) as f64 * p) as usize];
        Some(LatencyStats {
            count: v.len(),
            mean_ms: v.iter().sum::<f64>() / v.len() as f64,
            p50_ms: q(0.5),
            p90_ms: q(0.9),
            p99_ms: q(0.99),
            max_ms: *v.last().expect("non-empty"),
        })
    }
}

/// Aggregate service counters + latency histograms over every completed
/// request (the serve section of `BENCH_runtime.json` is built from this).
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub completed: usize,
    /// Total tokens emitted across completed requests.
    pub emitted_tokens: usize,
    /// Prompt consumptions performed (slot swap-ins + gang admissions).
    pub prefills: usize,
    /// Batched decode_step device calls driven by the loop.
    pub decode_steps: usize,
    pub queue_wait: Option<LatencyStats>,
    pub ttft: Option<LatencyStats>,
    pub per_token: Option<LatencyStats>,
}

/// A request occupying one batch row of the live decode state.
struct Slot {
    id: u64,
    prompt: Vec<i32>,
    sampler: RowSampler,
    /// Last sampled token — the slot's input to the next batched step.
    next_token: i32,
    prefill_artifact_tokens: usize,
    queue_wait_s: f64,
    ttft_s: f64,
    token_s: Vec<f64>,
}

struct Queued {
    req: Request,
    id: u64,
    submit_t: Instant,
}

/// The continuous-batching engine. Construct with the session that will
/// drive it, `submit` requests, and pump `step` (or `drain`) with that same
/// session; completed `Response`s come back from each call.
pub struct Engine {
    queue: VecDeque<Queued>,
    slots: Vec<Option<Slot>>,
    /// Live batched recurrent state; None until the first admission.
    state: Option<DecodeState>,
    batch: usize,
    vocab: usize,
    prefill_lens: Vec<usize>,
    /// SWA layouts read the shared `pos` scalar: gang admission only.
    position_dependent: bool,
    /// KV-cache capacity for full-attention layouts (manifest
    /// `decode.kv_cap`); None for rolling-window and pure-SSM state, whose
    /// footprint is position-invariant.
    kv_cap: Option<usize>,
    queue_cap: usize,
    next_id: u64,
    // Accumulators behind `report()`.
    completed: usize,
    emitted_tokens: usize,
    prefills: usize,
    decode_steps: usize,
    queue_wait_samples: Vec<f64>,
    ttft_samples: Vec<f64>,
    token_samples: Vec<f64>,
}

/// Request sanity against the manifest (free function so the CLI can check
/// lines before they ever reach the engine). `kv_cap` is the manifest's
/// `decode.kv_cap` (None for layouts without a capped KV lane): a prompt
/// longer than the cap can never be consumed, so it is rejected here;
/// prompts that fit but whose `max_new` would overrun the cap ARE admitted
/// and finish early with `FinishReason::KvCapExhausted`.
pub fn validate_request(req: &Request, vocab: usize, kv_cap: Option<usize>) -> Result<()> {
    if req.prompt.is_empty() {
        bail!("empty prompt");
    }
    if req.max_new == 0 {
        bail!("max-new must be >= 1 (got 0)");
    }
    if let Some(&t) = req.prompt.iter().find(|&&t| t < 0 || t as usize >= vocab) {
        bail!("token {t} outside the vocabulary [0, {vocab})");
    }
    if let Some(cap) = kv_cap {
        if req.prompt.len() > cap {
            bail!(
                "prompt of {} tokens exceeds the KV cache capacity {cap} \
                 (decode.kv_cap) — it can never be consumed",
                req.prompt.len()
            );
        }
    }
    Ok(())
}

impl Engine {
    pub fn new(sess: &Session, cfg: &ServeCfg) -> Result<Engine> {
        let spec = sess.bundle.decode_spec()?;
        if cfg.queue_cap == 0 {
            bail!("queue_cap must be >= 1");
        }
        Ok(Engine {
            queue: VecDeque::new(),
            slots: (0..spec.batch).map(|_| None).collect(),
            state: None,
            batch: spec.batch,
            vocab: sess.bundle.manifest.vocab_size,
            prefill_lens: spec.prefill_lens.clone(),
            position_dependent: spec.position_dependent(),
            kv_cap: spec.kv_cap,
            queue_cap: cfg.queue_cap,
            next_id: 0,
            completed: 0,
            emitted_tokens: 0,
            prefills: 0,
            decode_steps: 0,
            queue_wait_samples: Vec::new(),
            ttft_samples: Vec::new(),
            token_samples: Vec::new(),
        })
    }

    /// Enqueue a request. `Submit::Rejected` hands it back when the bounded
    /// queue is full (backpressure); `Err` means the request itself is
    /// invalid and retrying cannot help.
    pub fn submit(&mut self, req: Request) -> Result<Submit> {
        validate_request(&req, self.vocab, self.kv_cap)?;
        if self.queue.len() >= self.queue_cap {
            return Ok(Submit::Rejected(req));
        }
        let id = self.next_id;
        self.next_id += 1;
        self.queue.push_back(Queued { req, id, submit_t: Instant::now() });
        Ok(Submit::Accepted(id))
    }

    /// No queued and no in-flight work.
    pub fn idle(&self) -> bool {
        self.queue.is_empty() && self.slots.iter().all(|s| s.is_none())
    }

    /// Requests waiting for a slot.
    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// Requests currently occupying slots.
    pub fn active(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// Advance the service: admit queued prompts into free slots, then run
    /// at most one batched decode step. Returns the requests that completed
    /// during this call. Guaranteed progress: a non-idle engine always
    /// admits or decodes.
    pub fn step(&mut self, sess: &Session) -> Result<Vec<Response>> {
        let mut done = Vec::new();
        if self.position_dependent {
            self.admit_gang(sess, &mut done)?;
        } else {
            self.admit_slots(sess, &mut done)?;
        }
        self.decode_once(sess, &mut done)?;
        Ok(done)
    }

    /// Pump `step` until idle (the batch-mode tail of the CLI loop).
    pub fn drain(&mut self, sess: &Session) -> Result<Vec<Response>> {
        let mut out = Vec::new();
        while !self.idle() {
            out.extend(self.step(sess)?);
        }
        Ok(out)
    }

    /// Aggregate counters + latency histograms over completed requests.
    pub fn report(&self) -> ServeReport {
        ServeReport {
            completed: self.completed,
            emitted_tokens: self.emitted_tokens,
            prefills: self.prefills,
            decode_steps: self.decode_steps,
            queue_wait: LatencyStats::from_secs(&self.queue_wait_samples),
            ttft: LatencyStats::from_secs(&self.ttft_samples),
            per_token: LatencyStats::from_secs(&self.token_samples),
        }
    }

    // ---- admission ---------------------------------------------------------

    /// Position-invariant layouts: fill every free slot from the queue, one
    /// swap-in per request. Each admission consumes the prompt on a scratch
    /// state (rows replicated, so every row carries the same lanes) and
    /// injects one row into the freed slot of the live state.
    fn admit_slots(&mut self, sess: &Session, done: &mut Vec<Response>) -> Result<()> {
        while !self.queue.is_empty() {
            let Some(r) = self.slots.iter().position(|s| s.is_none()) else { break };
            let q = self.queue.pop_front().expect("checked non-empty");
            let queue_wait_s = q.submit_t.elapsed().as_secs_f64();
            let rows: Vec<&Vec<i32>> = vec![&q.req.prompt; self.batch];
            let (logits, scratch, artifact_tokens) = self.consume_prompt(sess, &rows)?;
            let lv = logits.as_f32()?;
            let mut sampler = sampler_for(&q.req);
            let first = sampler.sample(&lv[..self.vocab]);
            let ttft_s = q.submit_t.elapsed().as_secs_f64();
            let slot = Slot {
                id: q.id,
                prompt: q.req.prompt,
                sampler,
                next_token: first,
                prefill_artifact_tokens: artifact_tokens,
                queue_wait_s,
                ttft_s,
                token_s: Vec::new(),
            };
            if slot.sampler.finished() {
                // Completed at admission (max_new == 1 or instant stop):
                // never occupies the live state.
                self.complete(slot, None, done);
                continue;
            }
            if let Some(live) = self.state.as_mut() {
                sess.inject_state_row(live, r, &scratch, 0)?;
            } else {
                // Scratch rows are replicas, so row r already holds the
                // request's lanes — adopt the whole state on first use.
                self.state = Some(scratch);
            }
            self.slots[r] = Some(slot);
        }
        Ok(())
    }

    /// Position-dependent (SWA) layouts: every batch row must share the
    /// sequence position, so admission waits for ALL slots to free, then
    /// starts a FIFO run of equal-length prompts together on a fresh state.
    fn admit_gang(&mut self, sess: &Session, done: &mut Vec<Response>) -> Result<()> {
        if self.queue.is_empty() || self.slots.iter().any(|s| s.is_some()) {
            return Ok(());
        }
        let lead_len = self.queue[0].req.prompt.len();
        let take = self
            .queue
            .iter()
            .take(self.batch)
            .take_while(|q| q.req.prompt.len() == lead_len)
            .count();
        let gang: Vec<Queued> = self.queue.drain(..take).collect();
        let queue_waits: Vec<f64> =
            gang.iter().map(|q| q.submit_t.elapsed().as_secs_f64()).collect();

        let rows: Vec<&Vec<i32>> =
            (0..self.batch).map(|r| &gang.get(r).unwrap_or(&gang[0]).req.prompt).collect();
        self.state = None; // fresh sequence positions for the new gang
        let (logits, state, artifact_tokens) = self.consume_prompt(sess, &rows)?;
        let lv = logits.as_f32()?;
        self.state = Some(state);

        for (r, (q, queue_wait_s)) in gang.into_iter().zip(queue_waits).enumerate() {
            let mut sampler = sampler_for(&q.req);
            let first = sampler.sample(&lv[r * self.vocab..][..self.vocab]);
            let ttft_s = q.submit_t.elapsed().as_secs_f64();
            let slot = Slot {
                id: q.id,
                prompt: q.req.prompt,
                sampler,
                next_token: first,
                prefill_artifact_tokens: artifact_tokens,
                queue_wait_s,
                ttft_s,
                token_s: Vec::new(),
            };
            if slot.sampler.finished() {
                self.complete(slot, None, done);
            } else {
                self.slots[r] = Some(slot);
            }
        }
        Ok(())
    }

    /// Consume one prompt batch exactly as `generate` does — hybrid: the
    /// longest `prefill_L{L}` artifact with L <= len takes the prefix in one
    /// chunk-parallel device call, stepwise decode_step takes the tail (the
    /// whole prompt when no artifact fits). Returns the last-position
    /// logits, the resulting state and the artifact-consumed token count.
    fn consume_prompt(
        &mut self,
        sess: &Session,
        rows: &[&Vec<i32>],
    ) -> Result<(Tensor, DecodeState, usize)> {
        let len = rows[0].len();
        self.prefills += 1;
        let artifact_len = self.prefill_lens.iter().copied().filter(|&l| l <= len).max();
        let (mut logits, mut state) = match artifact_len {
            Some(l) => {
                let mut flat = Vec::with_capacity(self.batch * l);
                for row in rows {
                    flat.extend_from_slice(&row[..l]);
                }
                sess.prefill(&Tensor::i32(&[self.batch, l], flat))?
            }
            None => {
                let mut state = sess.init_decode_state()?;
                let toks: Vec<i32> = rows.iter().map(|r| r[0]).collect();
                let logits = sess.decode_step(&Tensor::i32(&[self.batch], toks), &mut state)?;
                (logits, state)
            }
        };
        for t in artifact_len.unwrap_or(1)..len {
            let toks: Vec<i32> = rows.iter().map(|r| r[t]).collect();
            logits = sess.decode_step(&Tensor::i32(&[self.batch], toks), &mut state)?;
        }
        Ok((logits, state, artifact_len.unwrap_or(0)))
    }

    // ---- decoding ----------------------------------------------------------

    /// One batched decode step: every occupied slot advances by one token;
    /// free rows are fed a zero token (their lanes are dead until the next
    /// swap-in overwrites them, and rows never interact).
    fn decode_once(&mut self, sess: &Session, done: &mut Vec<Response>) -> Result<()> {
        if self.slots.iter().all(|s| s.is_none()) {
            return Ok(());
        }
        // Full-attention cap check BEFORE the device call: the next step
        // would scatter its K/V row into cache slot `pos`, so once `pos`
        // reaches `kv_cap` there is no slot left — every in-flight request
        // is retired cleanly with what it has (each emitted >= 1 token at
        // admission). Stepping anyway would let XLA clamp the write index
        // and silently overwrite slot cap-1.
        if let (Some(cap), Some(state)) = (self.kv_cap, self.state.as_ref()) {
            if state.pos >= cap as u64 {
                for r in 0..self.batch {
                    if let Some(slot) = self.slots[r].take() {
                        self.complete(slot, Some(FinishReason::KvCapExhausted), done);
                    }
                }
                return Ok(());
            }
        }
        let mut toks = vec![0i32; self.batch];
        for (r, slot) in self.slots.iter().enumerate() {
            if let Some(s) = slot {
                toks[r] = s.next_token;
            }
        }
        let state = self.state.as_mut().expect("occupied slots imply live state");
        let t0 = Instant::now();
        let logits = sess.decode_step(&Tensor::i32(&[self.batch], toks), state)?;
        let dt = t0.elapsed().as_secs_f64();
        self.decode_steps += 1;
        let lv = logits.as_f32()?;
        if lv.len() != self.batch * self.vocab {
            bail!("decode logits: {} values, expected {}", lv.len(), self.batch * self.vocab);
        }
        let vocab = self.vocab;
        let mut finished = Vec::new();
        for (r, entry) in self.slots.iter_mut().enumerate() {
            let Some(slot) = entry else { continue };
            let tok = slot.sampler.sample(&lv[r * vocab..][..vocab]);
            slot.token_s.push(dt);
            if slot.sampler.finished() {
                finished.push(r);
            } else {
                slot.next_token = tok;
            }
        }
        for r in finished {
            let slot = self.slots[r].take().expect("just finished");
            self.complete(slot, None, done);
        }
        Ok(())
    }

    /// Retire a finished slot into a `Response` and fold its latencies into
    /// the service histograms. `forced` overrides the sampler-derived reason
    /// (the KV-cap exhaustion path ends requests whose samplers would have
    /// kept going).
    fn complete(&mut self, slot: Slot, forced: Option<FinishReason>, done: &mut Vec<Response>) {
        let finish = forced.unwrap_or(match slot.sampler.stop {
            Some(s) if slot.sampler.emitted.last() == Some(&s) => FinishReason::Stop,
            _ => FinishReason::MaxNew,
        });
        self.completed += 1;
        self.emitted_tokens += slot.sampler.emitted.len();
        self.queue_wait_samples.push(slot.queue_wait_s);
        self.ttft_samples.push(slot.ttft_s);
        self.token_samples.extend_from_slice(&slot.token_s);
        done.push(Response {
            id: slot.id,
            prompt: slot.prompt,
            tokens: slot.sampler.emitted,
            finish,
            prefill_artifact_tokens: slot.prefill_artifact_tokens,
            queue_wait_s: slot.queue_wait_s,
            ttft_s: slot.ttft_s,
            token_s: slot.token_s,
        });
    }
}

/// Fresh sampling state for one request (the `fold_in(0)` stream a
/// single-prompt `rom generate` run would use — the bit-identity contract).
fn sampler_for(req: &Request) -> RowSampler {
    RowSampler::new(
        Rng::new(req.seed).fold_in(0),
        req.temperature,
        req.top_k,
        req.max_new,
        req.stop,
    )
}

/// Parse one request line of the serve CLI: `TOKENS [key=val ...]` where
/// TOKENS follows the `--prompt-tokens` grammar (so `1,2;3,4` submits two
/// requests) and overrides are any of `max-new=N temperature=X top-k=K
/// seed=N stop=T`, applied on top of `defaults` for every prompt on the
/// line. Blank lines and `#` comments yield no requests.
pub fn parse_request_line(line: &str, defaults: &Request) -> Result<Vec<Request>> {
    let line = line.trim();
    if line.is_empty() || line.starts_with('#') {
        return Ok(Vec::new());
    }
    let mut parts = line.split_whitespace();
    let toks = parts.next().expect("non-empty line has a first field");
    let prompts = parse_prompt_tokens(toks)?;
    let mut base = defaults.clone();
    for kv in parts {
        let Some((k, v)) = kv.split_once('=') else {
            bail!("bad override {kv:?} (expected key=val)");
        };
        match k {
            "max-new" => base.max_new = parse_kv(k, v)?,
            "temperature" => base.temperature = parse_kv(k, v)?,
            "top-k" => base.top_k = parse_kv(k, v)?,
            "seed" => base.seed = parse_kv(k, v)?,
            "stop" => base.stop = Some(parse_kv(k, v)?),
            other => bail!("unknown override {other:?} (max-new/temperature/top-k/seed/stop)"),
        }
    }
    Ok(prompts
        .into_iter()
        .map(|prompt| Request { prompt, ..base.clone() })
        .collect())
}

fn parse_kv<T: std::str::FromStr>(k: &str, v: &str) -> Result<T> {
    v.parse().ok().with_context(|| format!("bad value {v:?} for {k}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_stats_quantiles_ordered() {
        let samples: Vec<f64> = (1..=100).map(|i| i as f64 * 1e-3).collect();
        let s = LatencyStats::from_secs(&samples).unwrap();
        assert_eq!(s.count, 100);
        assert!(s.p50_ms <= s.p90_ms && s.p90_ms <= s.p99_ms && s.p99_ms <= s.max_ms);
        assert_eq!(s.max_ms, 100.0);
        assert!((s.mean_ms - 50.5).abs() < 1e-9);
        assert!(LatencyStats::from_secs(&[]).is_none());
    }

    #[test]
    fn request_validation() {
        let ok = Request { prompt: vec![1, 2], ..Request::default() };
        assert!(validate_request(&ok, 10, None).is_ok());
        let empty = Request { prompt: vec![], ..Request::default() };
        assert!(validate_request(&empty, 10, None).is_err());
        let oov = Request { prompt: vec![1, 10], ..Request::default() };
        assert!(validate_request(&oov, 10, None).unwrap_err().to_string().contains("vocabulary"));
        let zero = Request { prompt: vec![1], max_new: 0, ..Request::default() };
        assert!(validate_request(&zero, 10, None).unwrap_err().to_string().contains("max-new"));
    }

    #[test]
    fn kv_cap_validation_rejects_only_unconsumable_prompts() {
        // Prompt longer than the cap can never be consumed: rejected.
        let long = Request { prompt: vec![1; 5], ..Request::default() };
        let err = validate_request(&long, 10, Some(4)).unwrap_err().to_string();
        assert!(err.contains("KV cache capacity 4"), "{err}");
        // Prompt that fits is admitted even when prompt + max_new would
        // overrun the cap — that request finishes with KvCapExhausted
        // instead of being bounced (the engine owns that cut-off).
        let tight = Request { prompt: vec![1; 4], max_new: 100, ..Request::default() };
        assert!(validate_request(&tight, 10, Some(4)).is_ok());
        // No cap (rolling-window / pure-SSM layouts): length-unbounded.
        assert!(validate_request(&long, 10, None).is_ok());
    }

    #[test]
    fn request_line_grammar() {
        let d = Request { max_new: 8, ..Request::default() };
        // Comments and blanks are silent.
        assert!(parse_request_line("", &d).unwrap().is_empty());
        assert!(parse_request_line("# a comment", &d).unwrap().is_empty());
        // Defaults flow through; `;` fans out into several requests.
        let rs = parse_request_line("1,2;3,4", &d).unwrap();
        assert_eq!(rs.len(), 2);
        assert_eq!(rs[0].prompt, vec![1, 2]);
        assert_eq!(rs[1].prompt, vec![3, 4]);
        assert!(rs.iter().all(|r| r.max_new == 8 && r.stop.is_none()));
        // Overrides apply to every prompt on the line.
        let rs = parse_request_line(
            "5,6 max-new=3 temperature=0.7 top-k=4 seed=9 stop=2",
            &d,
        )
        .unwrap();
        assert_eq!(rs.len(), 1);
        let r = &rs[0];
        assert_eq!((r.max_new, r.top_k, r.seed, r.stop), (3, 4, 9, Some(2)));
        assert!((r.temperature - 0.7).abs() < 1e-12);
        // Trailing `;` tolerated (same parser as --prompt-tokens).
        assert_eq!(parse_request_line("7,8;", &d).unwrap().len(), 1);
        // Malformed overrides and tokens are loud.
        assert!(parse_request_line("1,2 max-new", &d).is_err());
        assert!(parse_request_line("1,2 max-new=x", &d).is_err());
        assert!(parse_request_line("1,2 wat=3", &d).is_err());
        assert!(parse_request_line("1,x", &d).is_err());
    }

    #[test]
    fn finish_reason_from_sampler_state() {
        // `complete` derives Stop only when the LAST emitted token is the
        // stop token — mirrored here through the public sampler type.
        let mut s = RowSampler::new(Rng::new(0), 0.0, 0, 4, Some(1));
        s.sample(&[0.0, 5.0]); // emits 1 == stop
        assert!(s.finished());
        let mut m = RowSampler::new(Rng::new(0), 0.0, 0, 1, Some(7));
        m.sample(&[0.0, 5.0]); // emits 1, cap 1 reached, stop never seen
        assert!(m.finished());
        assert_ne!(m.emitted.last(), Some(&7));
    }
}
