//! The training coordinator: the L3 contribution glue.
//!
//! Owns the loop: two-stage data pipeline (window assembly -> device encode,
//! both on background threads, double-buffered) -> LR schedule -> fused step
//! (fast path) or microbatch grad-accum (memory path) -> sampled telemetry ->
//! periodic eval + checkpointing. The AOT artifact is the only compute; this
//! module never touches model math.
//!
//! The step loop consumes *device-ready* literals: `Tensor -> xla::Literal`
//! encode happens on the pipeline's second stage, so `Session` never blocks
//! on host-side encode between steps. Set `pipelined = false` to fall back to
//! the synchronous in-loop path (the determinism guard in
//! tests/integration_coordinator.rs pins the two paths to identical losses).

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::Result;

use crate::config::TrainCfg;
use crate::coordinator::checkpoint::{prune_checkpoints, Checkpoint};
use crate::coordinator::eval::eval_ppl_sweep;
use crate::coordinator::metrics::{Metrics, Throughput};
use crate::coordinator::monitor::ExpertMonitor;
use crate::coordinator::schedule::CosineSchedule;
use crate::data::corpus::{Corpus, CorpusSpec};
use crate::data::loader::{Batch, Loader};
use crate::{info, warnln};
use crate::runtime::artifact::{Bundle, Manifest};
use crate::runtime::session::Session;
use crate::runtime::tensor::{literal_from_i32, SendLiteral};
use crate::substrate::pool::Pipeline;

pub struct TrainReport {
    pub final_loss: f64,
    pub smoothed_loss: f64,
    pub tokens_per_sec: f64,
    pub metrics: Metrics,
    pub balance: crate::coordinator::monitor::BalanceReport,
    pub eval_ppl: Vec<(usize, f64)>,
}

/// One batch, already encoded for the device by the pipeline's second stage.
enum DeviceBatch {
    /// Full (B, T) pair for the fused step program.
    Fused { tokens: SendLiteral, targets: SendLiteral },
    /// (micro_batch, T) pairs for the grad-accum path.
    Micro(Vec<(SendLiteral, SendLiteral)>),
}

/// Stage-2 encode: host batch -> device literals. Shared by the pipelined and
/// synchronous paths so the bytes reaching the device are identical either way.
fn encode_batch(man: &Manifest, grad_accum: bool, batch: &Batch) -> Result<DeviceBatch> {
    if grad_accum {
        let micro = Loader::split_micro(batch, man.micro_batch);
        let enc = micro
            .iter()
            .map(|m| {
                Ok((
                    SendLiteral(literal_from_i32(&m.shape(), m.tokens)?),
                    SendLiteral(literal_from_i32(&m.shape(), m.targets)?),
                ))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(DeviceBatch::Micro(enc))
    } else {
        Ok(DeviceBatch::Fused {
            tokens: SendLiteral(batch.tokens.to_literal()?),
            targets: SendLiteral(batch.targets.to_literal()?),
        })
    }
}

pub struct Trainer {
    pub bundle: Arc<Bundle>,
    pub train_cfg: TrainCfg,
    pub corpus_seed: u64,
    pub checkpoint_dir: Option<PathBuf>,
    /// Keep only the newest N checkpoints of this variant in
    /// `checkpoint_dir` (`None` = unlimited). Pruning runs after every save,
    /// so long runs with a `checkpoint_every` cadence hold disk usage at
    /// N checkpoints instead of growing without bound.
    pub checkpoint_keep: Option<usize>,
    pub quiet: bool,
    /// Background assembly + encode (default). `false` runs both stages
    /// inline in the step loop — slower, but the same encode function on the
    /// same loader stream; kept as the baseline for the determinism guard.
    pub pipelined: bool,
    /// Run the final multi-length PPL sweep after the loop (default). Probe
    /// runs and wall-clock benches turn it off; the ROM_SKIP_EVAL=1 env
    /// escape hatch still applies on top.
    pub final_eval: bool,
}

impl Trainer {
    pub fn new(bundle: Arc<Bundle>, train_cfg: TrainCfg) -> Trainer {
        Trainer {
            bundle,
            train_cfg,
            corpus_seed: 17,
            checkpoint_dir: None,
            checkpoint_keep: None,
            quiet: false,
            pipelined: true,
            final_eval: true,
        }
    }

    /// Tokens needed to cover `steps` optimizer steps plus eval streams.
    fn stream_len(&self, steps: u64) -> usize {
        let man = &self.bundle.manifest;
        let per_step = man.batch_size * (man.seq_len + 1);
        (steps as usize + 2) * per_step
    }

    /// Run the full training loop; returns the report (and writes checkpoints
    /// if a directory is configured).
    pub fn run(&self) -> Result<TrainReport> {
        Ok(self.run_session()?.0)
    }

    /// Like `run`, but also hands back the trained session so callers can
    /// keep using the trained parameters (downstream probes, custom evals)
    /// without re-rolling their own training loop.
    pub fn run_session(&self) -> Result<(TrainReport, Session)> {
        let man = self.bundle.manifest.clone();
        let cfg = self.train_cfg.clone();
        let sched = CosineSchedule::new(cfg.max_lr, cfg.steps, cfg.warmup_ratio);

        // Data pipeline: corpus -> loader -> (assembly thread) -> (encode
        // thread) -> device-ready literals, double-buffered at each stage.
        let corpus = Corpus::new(CorpusSpec::default(), self.corpus_seed);
        let stream = corpus.generate(cfg.data_seed, self.stream_len(cfg.steps));
        let mut loader = Loader::new(stream, man.batch_size, man.seq_len, cfg.data_seed);
        let steps = cfg.steps;
        let grad_accum = cfg.grad_accum;
        // Encode failures travel through the channel as Err so `run` returns
        // them, instead of panicking an anonymous background thread.
        let mut source: Box<dyn FnMut() -> Option<Result<DeviceBatch>>> = if self.pipelined
        {
            let enc_man = man.clone();
            let pipeline = Pipeline::new(
                2,
                move || -> Option<Batch> { Some(loader.next_batch()) },
                move |batch: Batch| encode_batch(&enc_man, grad_accum, &batch),
            );
            Box::new(move || pipeline.next())
        } else {
            let enc_man = man.clone();
            Box::new(move || Some(encode_batch(&enc_man, grad_accum, &loader.next_batch())))
        };

        let mut sess = Session::init(Arc::clone(&self.bundle), 0)?;
        let mut metrics = Metrics::default();
        let mut thp = Throughput::new();
        let mut monitor = ExpertMonitor::new(man.num_routers, man.num_experts);
        let tokens_per_step = (man.batch_size * man.seq_len) as u64;

        for step in 1..=steps {
            let batch = source().expect("prefetch pipeline ended early")?;
            let lr = sched.lr(step) as f32;
            // Router telemetry costs a device->host transfer per decode;
            // sample it at the logging cadence instead of paying it every
            // step (the balance EMA converges the same either way).
            let decode_load =
                cfg.log_every > 0 && (step % cfg.log_every == 0 || step == steps);
            let out = match &batch {
                DeviceBatch::Micro(micro) => {
                    let refs: Vec<(&xla::Literal, &xla::Literal)> =
                        micro.iter().map(|(t, g)| (&t.0, &g.0)).collect();
                    sess.train_step_accum_device(lr, &refs, decode_load)?
                }
                DeviceBatch::Fused { tokens, targets } => {
                    sess.train_step_device(lr, &tokens.0, &targets.0, decode_load)?
                }
            };
            // Both paths feed the balance monitor now: the accum path samples
            // the last microbatch's load (None on legacy grad artifacts).
            if let Some(load) = &out.router_load {
                monitor.observe(load);
            }
            let loss = out.loss;
            thp.record(tokens_per_step);
            metrics.log_loss(step, loss, lr as f64, thp.total_tokens());

            if !self.quiet && cfg.log_every > 0 && step % cfg.log_every == 0 {
                let rate = thp.rate().unwrap_or(0.0);
                info!(
                    "[{}] step {step}/{steps} loss {loss:.4} lr {lr:.2e} {:.0} tok/s",
                    man.name, rate
                );
            }
            if cfg.eval_every > 0 && step % cfg.eval_every == 0 {
                for (ctx, ppl) in eval_ppl_sweep(&sess, &corpus, cfg.data_seed + 999, 4)? {
                    metrics.log_eval(step, ctx, ppl);
                    if !self.quiet {
                        info!("[{}] eval ctx {ctx}: ppl {ppl:.3}", man.name);
                    }
                }
            }
            if let Some(dir) = &self.checkpoint_dir {
                if cfg.checkpoint_every > 0 && step % cfg.checkpoint_every == 0 {
                    self.save_checkpoint(&sess, dir, step)?;
                }
            }
        }

        if let Some(dir) = &self.checkpoint_dir {
            self.save_checkpoint(&sess, dir, steps)?;
        }

        // ROM_SKIP_EVAL=1 (or `final_eval = false`) skips the final PPL sweep
        // — saves the per-length XLA compiles; used by probe runs and the
        // fast `cargo bench` sweep.
        let eval_ppl = if !self.final_eval
            || std::env::var("ROM_SKIP_EVAL").as_deref() == Ok("1")
        {
            Vec::new()
        } else {
            eval_ppl_sweep(&sess, &corpus, cfg.data_seed + 999, 8)?
        };
        let report = TrainReport {
            final_loss: metrics.last_loss().unwrap_or(f64::NAN),
            smoothed_loss: metrics.smoothed_loss(10).unwrap_or(f64::NAN),
            // Steady-state rate (sliding window) — excludes the one-time XLA
            // compile of the first step, which Table 11 must not charge.
            tokens_per_sec: thp.rate().unwrap_or_else(|| thp.overall_rate()),
            metrics,
            balance: monitor.report(),
            eval_ppl,
        };
        Ok((report, sess))
    }

    fn save_checkpoint(&self, sess: &Session, dir: &Path, step: u64) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        let (params, m, v) = sess.export()?;
        let ck = Checkpoint { step, params, m, v };
        let path = dir.join(format!("{}-step{step}.ckpt", self.bundle.manifest.name));
        ck.save(&path)?;
        info!("checkpoint written: {}", path.display());
        if let Some(keep) = self.checkpoint_keep {
            // Retention is best-effort: the checkpoint itself is already
            // safely on disk, so a pruning failure warns instead of
            // aborting the training run.
            match prune_checkpoints(dir, &self.bundle.manifest.name, keep, step) {
                Ok(pruned) => {
                    for p in pruned {
                        info!("pruned old checkpoint: {}", p.display());
                    }
                }
                Err(e) => warnln!("checkpoint retention failed (run continues): {e:#}"),
            }
        }
        Ok(())
    }
}
