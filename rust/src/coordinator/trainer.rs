//! The training coordinator: the L3 contribution glue.
//!
//! Owns the loop: two-stage data pipeline (window assembly -> device encode,
//! both on background threads, double-buffered) -> LR schedule -> fused step
//! (fast path) or microbatch grad-accum (memory path) -> sampled telemetry ->
//! periodic eval + checkpointing. The AOT artifact is the only compute; this
//! module never touches model math.
//!
//! The step loop consumes *device-ready* literals: `Tensor -> xla::Literal`
//! encode happens on the pipeline's second stage, so `Session` never blocks
//! on host-side encode between steps. Set `pipelined = false` to fall back to
//! the synchronous in-loop path (the determinism guard in
//! tests/integration_coordinator.rs pins the two paths to identical losses).

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::config::TrainCfg;
use crate::coordinator::checkpoint::{prune_checkpoints, Checkpoint};
use crate::coordinator::eval::eval_ppl_sweep;
use crate::coordinator::metrics::{Metrics, Throughput};
use crate::coordinator::monitor::ExpertMonitor;
use crate::coordinator::schedule::CosineSchedule;
use crate::data::corpus::{Corpus, CorpusSpec};
use crate::data::loader::{Batch, Loader};
use crate::{info, warnln};
use crate::runtime::artifact::{Bundle, Manifest};
use crate::runtime::session::{MicroGrad, Session};
use crate::runtime::tensor::{literal_from_i32, SendLiteral, Tensor};
use crate::substrate::pool::{
    panic_message, reduce_group, Pipeline, ReduceError, ReduceMember, ThreadPool,
};

pub struct TrainReport {
    pub final_loss: f64,
    pub smoothed_loss: f64,
    pub tokens_per_sec: f64,
    pub metrics: Metrics,
    pub balance: crate::coordinator::monitor::BalanceReport,
    pub eval_ppl: Vec<(usize, f64)>,
    /// Rank-0 timing of the data-parallel driver (`None` on the classic
    /// single-session paths): mean per-shard gradient time and mean
    /// reduce time (straggler wait + rank-ordered fold) per optimizer step.
    pub dp_stats: Option<DpStats>,
}

/// Per-step wall-clock split of a `--dp` run, measured on rank 0.
#[derive(Debug, Clone, Copy)]
pub struct DpStats {
    pub world: usize,
    pub shard_step_ms: f64,
    pub reduce_ms: f64,
}

/// One batch, already encoded for the device by the pipeline's second stage.
enum DeviceBatch {
    /// Full (B, T) pair for the fused step program.
    Fused { tokens: SendLiteral, targets: SendLiteral },
    /// (micro_batch, T) pairs for the grad-accum path.
    Micro(Vec<(SendLiteral, SendLiteral)>),
}

/// Stage-2 encode: host batch -> device literals. Shared by the pipelined and
/// synchronous paths so the bytes reaching the device are identical either way.
fn encode_batch(man: &Manifest, grad_accum: bool, batch: &Batch) -> Result<DeviceBatch> {
    if grad_accum {
        let micro = Loader::split_micro(batch, man.micro_batch);
        let enc = micro
            .iter()
            .map(|m| {
                Ok((
                    SendLiteral(literal_from_i32(&m.shape(), m.tokens)?),
                    SendLiteral(literal_from_i32(&m.shape(), m.targets)?),
                ))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(DeviceBatch::Micro(enc))
    } else {
        Ok(DeviceBatch::Fused {
            tokens: SendLiteral(batch.tokens.to_literal()?),
            targets: SendLiteral(batch.targets.to_literal()?),
        })
    }
}

pub struct Trainer {
    pub bundle: Arc<Bundle>,
    pub train_cfg: TrainCfg,
    pub corpus_seed: u64,
    pub checkpoint_dir: Option<PathBuf>,
    /// Keep only the newest N checkpoints of this variant in
    /// `checkpoint_dir` (`None` = unlimited). Pruning runs after every save,
    /// so long runs with a `checkpoint_every` cadence hold disk usage at
    /// N checkpoints instead of growing without bound.
    pub checkpoint_keep: Option<usize>,
    pub quiet: bool,
    /// Background assembly + encode (default). `false` runs both stages
    /// inline in the step loop — slower, but the same encode function on the
    /// same loader stream; kept as the baseline for the determinism guard.
    pub pipelined: bool,
    /// Run the final multi-length PPL sweep after the loop (default). Probe
    /// runs and wall-clock benches turn it off; the ROM_SKIP_EVAL=1 env
    /// escape hatch still applies on top.
    pub final_eval: bool,
    /// Data-parallel replica count (`rom train --dp K` / ROM_DP). `None`
    /// runs the classic single-session loop above; `Some(k)` runs the
    /// per-replica driver + host-side reduce/apply loop — including
    /// `Some(1)`, which is the dp baseline: the bit-identity contract
    /// (`--dp K` == `--dp 1` at the same global batch) holds *within* the
    /// dp path, whose per-microbatch raw-gradient sum is a different (but
    /// fixed) float association than the fused/accum device paths.
    pub dp: Option<usize>,
    /// Test seam: panic replica `.0` at step `.1` — exercises the per-rank
    /// failure isolation path (run fails naming the rank, peers drain).
    #[doc(hidden)]
    pub dp_fault: Option<(usize, u64)>,
}

impl Trainer {
    pub fn new(bundle: Arc<Bundle>, train_cfg: TrainCfg) -> Trainer {
        Trainer {
            bundle,
            train_cfg,
            corpus_seed: 17,
            checkpoint_dir: None,
            checkpoint_keep: None,
            quiet: false,
            pipelined: true,
            final_eval: true,
            dp: None,
            dp_fault: None,
        }
    }

    /// Tokens needed to cover `steps` optimizer steps plus eval streams.
    fn stream_len(&self, steps: u64) -> usize {
        let man = &self.bundle.manifest;
        let per_step = man.batch_size * (man.seq_len + 1);
        (steps as usize + 2) * per_step
    }

    /// Run the full training loop; returns the report (and writes checkpoints
    /// if a directory is configured).
    pub fn run(&self) -> Result<TrainReport> {
        Ok(self.run_session()?.0)
    }

    /// Like `run`, but also hands back the trained session so callers can
    /// keep using the trained parameters (downstream probes, custom evals)
    /// without re-rolling their own training loop.
    pub fn run_session(&self) -> Result<(TrainReport, Session)> {
        if let Some(world) = self.dp {
            return self.run_session_dp(world);
        }
        let man = self.bundle.manifest.clone();
        let cfg = self.train_cfg.clone();
        let sched = CosineSchedule::new(cfg.max_lr, cfg.steps, cfg.warmup_ratio);

        // Data pipeline: corpus -> loader -> (assembly thread) -> (encode
        // thread) -> device-ready literals, double-buffered at each stage.
        let corpus = Corpus::new(CorpusSpec::default(), self.corpus_seed);
        let stream = corpus.generate(cfg.data_seed, self.stream_len(cfg.steps));
        let mut loader = Loader::new(stream, man.batch_size, man.seq_len, cfg.data_seed);
        let steps = cfg.steps;
        let grad_accum = cfg.grad_accum;
        // Encode failures travel through the channel as Err so `run` returns
        // them, instead of panicking an anonymous background thread.
        let mut source: Box<dyn FnMut() -> Option<Result<DeviceBatch>>> = if self.pipelined
        {
            let enc_man = man.clone();
            let pipeline = Pipeline::new(
                2,
                move || -> Option<Batch> { Some(loader.next_batch()) },
                move |batch: Batch| encode_batch(&enc_man, grad_accum, &batch),
            );
            Box::new(move || pipeline.next())
        } else {
            let enc_man = man.clone();
            Box::new(move || Some(encode_batch(&enc_man, grad_accum, &loader.next_batch())))
        };

        let mut sess = Session::init(Arc::clone(&self.bundle), 0)?;
        let mut metrics = Metrics::default();
        let mut thp = Throughput::new();
        let mut monitor = ExpertMonitor::new(man.num_routers, man.num_experts);
        let tokens_per_step = (man.batch_size * man.seq_len) as u64;

        for step in 1..=steps {
            let batch = source().expect("prefetch pipeline ended early")?;
            let lr = sched.lr(step) as f32;
            // Router telemetry costs a device->host transfer per decode;
            // sample it at the logging cadence instead of paying it every
            // step (the balance EMA converges the same either way).
            let decode_load =
                cfg.log_every > 0 && (step % cfg.log_every == 0 || step == steps);
            let out = match &batch {
                DeviceBatch::Micro(micro) => {
                    let refs: Vec<(&xla::Literal, &xla::Literal)> =
                        micro.iter().map(|(t, g)| (&t.0, &g.0)).collect();
                    sess.train_step_accum_device(lr, &refs, decode_load)?
                }
                DeviceBatch::Fused { tokens, targets } => {
                    sess.train_step_device(lr, &tokens.0, &targets.0, decode_load)?
                }
            };
            // Both paths feed the balance monitor now: the accum path samples
            // the last microbatch's load (None on legacy grad artifacts).
            if let Some(load) = &out.router_load {
                monitor.observe(load);
            }
            let loss = out.loss;
            thp.record(tokens_per_step);
            metrics.log_loss(step, loss, lr as f64, thp.total_tokens());

            if !self.quiet && cfg.log_every > 0 && step % cfg.log_every == 0 {
                let rate = thp.rate().unwrap_or(0.0);
                info!(
                    "[{}] step {step}/{steps} loss {loss:.4} lr {lr:.2e} {:.0} tok/s",
                    man.name, rate
                );
            }
            if cfg.eval_every > 0 && step % cfg.eval_every == 0 {
                for (ctx, ppl) in eval_ppl_sweep(&sess, &corpus, cfg.data_seed + 999, 4)? {
                    metrics.log_eval(step, ctx, ppl);
                    if !self.quiet {
                        info!("[{}] eval ctx {ctx}: ppl {ppl:.3}", man.name);
                    }
                }
            }
            if let Some(dir) = &self.checkpoint_dir {
                if cfg.checkpoint_every > 0 && step % cfg.checkpoint_every == 0 {
                    self.save_checkpoint(&sess, dir, step)?;
                }
            }
        }

        if let Some(dir) = &self.checkpoint_dir {
            self.save_checkpoint(&sess, dir, steps)?;
        }

        // ROM_SKIP_EVAL=1 (or `final_eval = false`) skips the final PPL sweep
        // — saves the per-length XLA compiles; used by probe runs and the
        // fast `cargo bench` sweep.
        let eval_ppl = if !self.final_eval
            || std::env::var("ROM_SKIP_EVAL").as_deref() == Ok("1")
        {
            Vec::new()
        } else {
            eval_ppl_sweep(&sess, &corpus, cfg.data_seed + 999, 8)?
        };
        let report = TrainReport {
            final_loss: metrics.last_loss().unwrap_or(f64::NAN),
            smoothed_loss: metrics.smoothed_loss(10).unwrap_or(f64::NAN),
            // Steady-state rate (sliding window) — excludes the one-time XLA
            // compile of the first step, which Table 11 must not charge.
            tokens_per_sec: thp.rate().unwrap_or_else(|| thp.overall_rate()),
            metrics,
            balance: monitor.report(),
            eval_ppl,
            dp_stats: None,
        };
        Ok((report, sess))
    }

    /// Data-parallel driver: `world` replicas, each owning its own PJRT
    /// client + session on an equal loader shard (batch B/world), exchange
    /// gradients host-side every step through a rank-ordered rendezvous
    /// reduce and all apply the same reduced update — parameters therefore
    /// stay bit-identical across replicas for the whole run, and rank 0
    /// (on the caller's thread, since sessions are thread-affine) alone
    /// owns metrics, eval, checkpointing and the returned session.
    fn run_session_dp(&self, world: usize) -> Result<(TrainReport, Session)> {
        let man = &self.bundle.manifest;
        if world == 0 {
            bail!("--dp 0: need at least one replica");
        }
        if man.batch_size % world != 0 {
            bail!(
                "--dp {world} does not divide the batch size {} of '{}'",
                man.batch_size,
                man.name
            );
        }
        let shard_batch = man.batch_size / world;
        if shard_batch % man.micro_batch != 0 {
            bail!(
                "--dp {world}: shard batch {shard_batch} is not a multiple of \
                 micro batch {} ('{}' exchanges whole microbatch gradients)",
                man.micro_batch,
                man.name
            );
        }
        let mut members = reduce_group(world, fold_rank_steps);
        if world == 1 {
            let member = members.pop().expect("one member for world 1");
            return self.dp_primary(1, member);
        }

        // Ranks 1..world run on pool threads; panics are caught inside the
        // job (a panicking pool worker would wedge the in-flight accounting)
        // and every worker reports exactly once, so the drain below always
        // terminates. A dying worker drops its reduce member on the way out,
        // which wakes every peer parked in the barrier with an error.
        let pool = ThreadPool::new(world - 1);
        let (tx, rx) = channel::<(usize, Result<()>)>();
        for rank in (1..world).rev() {
            let member = members.pop().expect("one member per rank");
            let tx = tx.clone();
            let dir = self.bundle.dir.clone();
            let cfg = self.train_cfg.clone();
            let corpus_seed = self.corpus_seed;
            let stream_len = self.stream_len(self.train_cfg.steps);
            let fault = self.dp_fault;
            pool.submit(move || {
                let res = catch_unwind(AssertUnwindSafe(|| {
                    dp_worker(&dir, &cfg, corpus_seed, stream_len, member, rank, world, fault)
                }))
                .unwrap_or_else(|payload| {
                    Err(anyhow!("replica panicked: {}", panic_message(payload.as_ref())))
                });
                let _ = tx.send((rank, res));
            });
        }
        drop(tx);

        let member0 = members.pop().expect("rank 0 member");
        // On any rank-0 failure the member drops inside `dp_primary`, so
        // blocked workers wake and the drain cannot hang.
        let primary = self.dp_primary(world, member0);

        let mut results: Vec<(usize, Result<()>)> = rx.into_iter().collect();
        pool.join();
        results.sort_by_key(|(rank, _)| *rank);
        let mut secondary = 0usize;
        let mut genuine: Option<(usize, anyhow::Error)> = None;
        for (rank, res) in results {
            if let Err(e) = res {
                if e.downcast_ref::<ReduceError>().is_some() {
                    // The replica aborted because a *peer* departed — a
                    // consequence, not the root cause.
                    secondary += 1;
                } else if genuine.is_none() {
                    genuine = Some((rank, e));
                }
            }
        }
        if let Some((rank, e)) = genuine {
            return Err(e.context(format!(
                "dp replica {rank} failed (remaining replicas drained cleanly)"
            )));
        }
        let (report, sess) = primary?;
        if secondary > 0 {
            bail!("{secondary} dp replica(s) aborted mid-reduce with no root cause reported");
        }
        Ok((report, sess))
    }

    /// Rank 0 of the dp group: the only replica that logs, evals,
    /// checkpoints and returns its session. Runs on the caller's thread
    /// (sessions hold thread-affine PJRT handles, and `run_session` must
    /// hand the trained session back).
    fn dp_primary(
        &self,
        world: usize,
        member: ReduceMember<RankStep, ReducedStep>,
    ) -> Result<(TrainReport, Session)> {
        let man = self.bundle.manifest.clone();
        let cfg = self.train_cfg.clone();
        let sched = CosineSchedule::new(cfg.max_lr, cfg.steps, cfg.warmup_ratio);
        let corpus = Corpus::new(CorpusSpec::default(), self.corpus_seed);
        let stream = corpus.generate(cfg.data_seed, self.stream_len(cfg.steps));
        let mut loader = Loader::sharded(
            stream,
            man.batch_size / world,
            man.seq_len,
            cfg.data_seed,
            world,
            0,
        );
        let mut sess = Session::init(Arc::clone(&self.bundle), 0)?;
        let mut metrics = Metrics::default();
        let mut thp = Throughput::new();
        let mut monitor = ExpertMonitor::new(man.num_routers, man.num_experts);
        // Rank 0 accounts the GLOBAL batch: the step completes for all
        // replicas at the reduce barrier, so its cadence is the run's.
        let tokens_per_step = (man.batch_size * man.seq_len) as u64;
        let (mut shard_secs, mut reduce_secs) = (0.0f64, 0.0f64);

        for step in 1..=cfg.steps {
            if self.dp_fault == Some((0, step)) {
                panic!("dp fault injection: replica 0 at step {step}");
            }
            let lr = sched.lr(step) as f32;
            let decode_load =
                cfg.log_every > 0 && (step % cfg.log_every == 0 || step == cfg.steps);
            let t_shard = Instant::now();
            // Only the LAST rank decodes router telemetry: the fold keeps
            // the final microbatch's sample (matching the accum path), so
            // any other rank's decode would be a wasted transfer.
            let contrib = dp_shard_grads(&sess, &man, &mut loader, decode_load && world == 1)?;
            let t_reduce = Instant::now();
            let reduced = member.reduce(contrib).map_err(|e| {
                anyhow::Error::new(e).context("replica 0: a peer replica departed mid-reduce")
            })?;
            shard_secs += t_reduce.duration_since(t_shard).as_secs_f64();
            reduce_secs += t_reduce.elapsed().as_secs_f64();
            sess.apply_reduced(lr, &reduced.grads, reduced.num_micro)?;
            if let Some(load) = &reduced.router_load {
                monitor.observe(load);
            }
            let loss = reduced.loss;
            thp.record(tokens_per_step);
            metrics.log_loss(step, loss, lr as f64, thp.total_tokens());

            if !self.quiet && cfg.log_every > 0 && step % cfg.log_every == 0 {
                let rate = thp.rate().unwrap_or(0.0);
                info!(
                    "[{}] dp{world} step {step}/{} loss {loss:.4} lr {lr:.2e} {:.0} tok/s",
                    man.name, cfg.steps, rate
                );
            }
            if cfg.eval_every > 0 && step % cfg.eval_every == 0 {
                for (ctx, ppl) in eval_ppl_sweep(&sess, &corpus, cfg.data_seed + 999, 4)? {
                    metrics.log_eval(step, ctx, ppl);
                    if !self.quiet {
                        info!("[{}] eval ctx {ctx}: ppl {ppl:.3}", man.name);
                    }
                }
            }
            if let Some(dir) = &self.checkpoint_dir {
                if cfg.checkpoint_every > 0 && step % cfg.checkpoint_every == 0 {
                    self.save_checkpoint(&sess, dir, step)?;
                }
            }
        }

        if let Some(dir) = &self.checkpoint_dir {
            self.save_checkpoint(&sess, dir, cfg.steps)?;
        }
        let eval_ppl = if !self.final_eval
            || std::env::var("ROM_SKIP_EVAL").as_deref() == Ok("1")
        {
            Vec::new()
        } else {
            eval_ppl_sweep(&sess, &corpus, cfg.data_seed + 999, 8)?
        };
        let steps = cfg.steps.max(1) as f64;
        let report = TrainReport {
            final_loss: metrics.last_loss().unwrap_or(f64::NAN),
            smoothed_loss: metrics.smoothed_loss(10).unwrap_or(f64::NAN),
            tokens_per_sec: thp.rate().unwrap_or_else(|| thp.overall_rate()),
            metrics,
            balance: monitor.report(),
            eval_ppl,
            dp_stats: Some(DpStats {
                world,
                shard_step_ms: shard_secs * 1e3 / steps,
                reduce_ms: reduce_secs * 1e3 / steps,
            }),
        };
        Ok((report, sess))
    }

    fn save_checkpoint(&self, sess: &Session, dir: &Path, step: u64) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        let (params, m, v) = sess.export()?;
        let ck = Checkpoint { step, params, m, v };
        let path = dir.join(format!("{}-step{step}.ckpt", self.bundle.manifest.name));
        ck.save(&path)?;
        info!("checkpoint written: {}", path.display());
        if let Some(keep) = self.checkpoint_keep {
            // Retention is best-effort: the checkpoint itself is already
            // safely on disk, so a pruning failure warns instead of
            // aborting the training run.
            match prune_checkpoints(dir, &self.bundle.manifest.name, keep, step) {
                Ok(pruned) => {
                    for p in pruned {
                        info!("pruned old checkpoint: {}", p.display());
                    }
                }
                Err(e) => warnln!("checkpoint retention failed (run continues): {e:#}"),
            }
        }
        Ok(())
    }
}

/// One rank's contribution to a dp step: its shard's raw microbatch
/// gradients, in microbatch order. `Tensor` payloads are plain host vecs,
/// so the contribution crosses the reduce barrier without touching any
/// thread-affine device handle.
struct RankStep {
    micro: Vec<MicroGrad>,
}

/// The rank-ordered fold of one dp step.
struct ReducedStep {
    grads: Vec<Tensor>,
    loss: f64,
    num_micro: usize,
    router_load: Option<Vec<f32>>,
}

/// Flat, rank-major, left-to-right f32 fold over ALL microbatch gradients
/// of one step. The association never mentions `world`: dp=K and dp=1 sum
/// the same `B / micro_batch` raw gradients in the same global order, which
/// is exactly why the reduced bits (and the f64 loss sum) are identical for
/// every K. Contributions arrive rank-ordered by construction — the reduce
/// group drains its slots in rank order regardless of thread scheduling.
fn fold_rank_steps(contribs: Vec<RankStep>) -> ReducedStep {
    let mut grads: Option<Vec<Tensor>> = None;
    let mut loss_sum = 0.0f64;
    let mut num_micro = 0usize;
    let mut router_load: Option<Vec<f32>> = None;
    for rank_step in contribs {
        for mg in rank_step.micro {
            num_micro += 1;
            loss_sum += mg.loss;
            if mg.router_load.is_some() {
                // Keep the globally-last sample — matches the accum path's
                // last-microbatch telemetry convention.
                router_load = mg.router_load;
            }
            match &mut grads {
                None => grads = Some(mg.grads),
                Some(acc) => {
                    for (a, g) in acc.iter_mut().zip(mg.grads.iter()) {
                        a.accumulate(g).expect("gradient leaves align across replicas");
                    }
                }
            }
        }
    }
    ReducedStep {
        grads: grads.expect("reduce round without microbatches"),
        loss: loss_sum / num_micro.max(1) as f64,
        num_micro,
        router_load,
    }
}

/// One replica's half-step: pull its shard batch, run the grad program per
/// microbatch, decode the raw gradients to host. Shared by rank 0 and the
/// pool workers so every replica computes byte-identical contributions.
fn dp_shard_grads(
    sess: &Session,
    man: &Manifest,
    loader: &mut Loader,
    decode_router_load: bool,
) -> Result<RankStep> {
    let batch = loader.next_batch();
    let micro = Loader::split_micro(&batch, man.micro_batch);
    let mut out = Vec::with_capacity(micro.len());
    for m in &micro {
        let tok = literal_from_i32(&m.shape(), m.tokens)?;
        let tgt = literal_from_i32(&m.shape(), m.targets)?;
        out.push(sess.grad_to_host(&tok, &tgt, decode_router_load)?);
    }
    Ok(RankStep { micro: out })
}

/// A non-zero rank of the dp group: own PJRT client + session (the
/// one-client-per-worker ownership model of the sweep scheduler), identical
/// init seed — so parameters start bit-identical to rank 0's and stay that
/// way, since every replica applies the same reduced gradient each step.
/// No logging, no eval, no checkpointing: rank 0 owns all side effects.
#[allow(clippy::too_many_arguments)]
fn dp_worker(
    dir: &Path,
    cfg: &TrainCfg,
    corpus_seed: u64,
    stream_len: usize,
    member: ReduceMember<RankStep, ReducedStep>,
    rank: usize,
    world: usize,
    fault: Option<(usize, u64)>,
) -> Result<()> {
    let bundle = Bundle::open(dir)?;
    let man = bundle.manifest.clone();
    let corpus = Corpus::new(CorpusSpec::default(), corpus_seed);
    let stream = corpus.generate(cfg.data_seed, stream_len);
    let mut loader = Loader::sharded(
        stream,
        man.batch_size / world,
        man.seq_len,
        cfg.data_seed,
        world,
        rank,
    );
    let mut sess = Session::init(bundle, 0)?;
    let sched = CosineSchedule::new(cfg.max_lr, cfg.steps, cfg.warmup_ratio);
    for step in 1..=cfg.steps {
        if fault == Some((rank, step)) {
            panic!("dp fault injection: replica {rank} at step {step}");
        }
        let lr = sched.lr(step) as f32;
        // Same sampling cadence as rank 0 (purely step-derived, so every
        // replica computes it identically); only the last rank decodes.
        let decode_load =
            cfg.log_every > 0 && (step % cfg.log_every == 0 || step == cfg.steps);
        let contrib =
            dp_shard_grads(&sess, &man, &mut loader, decode_load && rank + 1 == world)?;
        let reduced = member.reduce(contrib).map_err(|e| {
            anyhow::Error::new(e)
                .context(format!("replica {rank}: a peer replica departed mid-reduce"))
        })?;
        sess.apply_reduced(lr, &reduced.grads, reduced.num_micro)?;
    }
    Ok(())
}
