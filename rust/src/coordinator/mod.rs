//! L3 coordinator: the training-orchestration layer (DESIGN.md §2).
pub mod checkpoint;
pub mod downstream;
pub mod eval;
pub mod generate;
pub mod metrics;
pub mod monitor;
pub mod schedule;
pub mod serve;
pub mod trainer;
