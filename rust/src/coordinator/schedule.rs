//! Learning-rate schedule (paper §5.1): linear warmup over `warmup_ratio` of
//! total steps, then cosine decay from `max_lr` to `min_lr`.

#[derive(Debug, Clone)]
pub struct CosineSchedule {
    pub max_lr: f64,
    pub min_lr: f64,
    pub total_steps: u64,
    pub warmup_steps: u64,
}

impl CosineSchedule {
    pub fn new(max_lr: f64, total_steps: u64, warmup_ratio: f64) -> Self {
        let warmup_steps = ((total_steps as f64) * warmup_ratio).ceil() as u64;
        CosineSchedule { max_lr, min_lr: max_lr * 0.1, total_steps, warmup_steps: warmup_steps.max(1) }
    }

    /// LR for a 1-based step index.
    pub fn lr(&self, step: u64) -> f64 {
        if step <= self.warmup_steps {
            return self.max_lr * step as f64 / self.warmup_steps as f64;
        }
        if step >= self.total_steps {
            return self.min_lr;
        }
        let t = (step - self.warmup_steps) as f64
            / (self.total_steps - self.warmup_steps) as f64;
        let cos = 0.5 * (1.0 + (std::f64::consts::PI * t).cos());
        self.min_lr + (self.max_lr - self.min_lr) * cos
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::proptest::{check, Config};

    #[test]
    fn warmup_starts_low_peaks_at_max() {
        let s = CosineSchedule::new(4e-4, 1000, 0.01);
        assert!(s.lr(1) < 4e-4 * 0.2);
        assert!((s.lr(s.warmup_steps) - 4e-4).abs() < 1e-9);
    }

    #[test]
    fn decays_to_min() {
        let s = CosineSchedule::new(4e-4, 1000, 0.01);
        assert!((s.lr(1000) - 4e-5).abs() < 1e-9);
        assert!(s.lr(1500) == s.lr(1000));
    }

    #[test]
    fn prop_bounded_and_post_warmup_monotone() {
        check("lr-bounds", Config::default(), |rng| {
            let total = 10 + rng.below(10_000);
            let s = CosineSchedule::new(1e-3, total, 0.05);
            let mut prev = f64::INFINITY;
            for step in 1..=total {
                let lr = s.lr(step);
                crate::prop_assert!(lr > 0.0 && lr <= 1e-3 + 1e-12,
                    "lr {lr} out of bounds at {step}/{total}");
                if step > s.warmup_steps {
                    crate::prop_assert!(lr <= prev + 1e-12,
                        "lr not monotone after warmup at {step}");
                }
                prev = lr;
            }
            Ok(())
        });
    }
}
