//! Binary checkpoint format for (params, m, v, step) state.
//!
//! Layout: magic "ROMCKPT1" | u64 header_len | header JSON (leaf names,
//! shapes, dtypes, step, offsets) | raw little-endian tensor payloads.
//! JSON-in-header keeps the format self-describing; raw payloads keep a
//! multi-MB state fast to write/restore (a pure-JSON checkpoint would be
//! ~10x larger and slower to parse).
//!
//! Both directions stream: `save` precomputes payload offsets from the tensor
//! shapes and writes each leaf through a `BufWriter` (peak extra host memory
//! is one buffer, not a full model-size `Vec<u8>`); `load` seeks to each
//! leaf's offset and reads it through a single reusable scratch buffer.

use std::io::{BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::runtime::tensor::{DType, Tensor};
use crate::substrate::json::Json;

const MAGIC: &[u8; 8] = b"ROMCKPT1";
/// magic + header-length prefix.
const PREAMBLE_LEN: u64 = 16;

pub struct Checkpoint {
    pub step: u64,
    pub params: Vec<Tensor>,
    pub m: Vec<Tensor>,
    pub v: Vec<Tensor>,
}

impl Checkpoint {
    pub fn save(&self, path: &Path) -> Result<()> {
        let groups: [(&str, &Vec<Tensor>); 3] =
            [("params", &self.params), ("m", &self.m), ("v", &self.v)];

        // Pass 1 (metadata only): assign contiguous payload offsets from the
        // shapes — no payload bytes are materialized.
        let mut offset = 0usize;
        let mut header_groups = Vec::new();
        for (name, tensors) in groups {
            let mut specs = Vec::new();
            for t in tensors.iter() {
                specs.push(Json::obj(vec![
                    ("shape", Json::arr_usize(&t.shape)),
                    ("dtype", Json::str(t.dtype().name())),
                    ("offset", Json::num(offset as f64)),
                ]));
                offset += t.byte_len();
            }
            header_groups.push((name, Json::Arr(specs)));
        }
        let header = Json::obj(vec![
            ("step", Json::num(self.step as f64)),
            ("params", header_groups[0].1.clone()),
            ("m", header_groups[1].1.clone()),
            ("v", header_groups[2].1.clone()),
        ])
        .to_string();

        // Pass 2: stream preamble + header + per-leaf payloads.
        let tmp = path.with_extension("tmp");
        {
            let f = std::fs::File::create(&tmp)
                .with_context(|| format!("creating {}", tmp.display()))?;
            let mut w = BufWriter::new(f);
            w.write_all(MAGIC)?;
            w.write_all(&(header.len() as u64).to_le_bytes())?;
            w.write_all(header.as_bytes())?;
            for (_, tensors) in groups {
                for t in tensors.iter() {
                    t.write_le_bytes(&mut w)?;
                }
            }
            w.flush()?;
            w.get_ref().sync_all()?;
        }
        std::fs::rename(&tmp, path)?; // atomic publish
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let f = std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?;
        let file_len = f.metadata()?.len();
        let mut r = BufReader::new(f);
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic)
            .with_context(|| format!("{}: truncated inside the 16-byte preamble", path.display()))?;
        if &magic != MAGIC {
            bail!("{} is not a ROM checkpoint", path.display());
        }
        let mut len8 = [0u8; 8];
        r.read_exact(&mut len8)
            .with_context(|| format!("{}: truncated inside the 16-byte preamble", path.display()))?;
        let hlen = u64::from_le_bytes(len8);
        // Reject a corrupt length prefix before trusting it as an allocation
        // size: the header cannot extend past the file.
        if hlen > file_len.saturating_sub(PREAMBLE_LEN) {
            bail!(
                "{}: corrupt header length {hlen} (file is {file_len} bytes)",
                path.display()
            );
        }
        let mut hbuf = vec![0u8; hlen as usize];
        r.read_exact(&mut hbuf)
            .with_context(|| {
                format!("{}: truncated inside the {hlen}-byte header", path.display())
            })?;
        let header = Json::parse(std::str::from_utf8(&hbuf)?)?;
        let payload_base = PREAMBLE_LEN + hlen;
        let payload_len = (file_len - payload_base) as usize;

        // Stream each leaf through one reusable scratch buffer.
        let mut scratch: Vec<u8> = Vec::new();
        let mut read_group = |name: &str| -> Result<Vec<Tensor>> {
            header
                .get(name)?
                .as_arr()?
                .iter()
                .enumerate()
                .map(|(i, spec)| {
                    let shape: Vec<usize> = spec
                        .get("shape")?
                        .as_arr()?
                        .iter()
                        .map(|v| v.as_usize())
                        .collect::<Result<_, _>>()?;
                    let dtype = DType::from_str(spec.get("dtype")?.as_str()?)?;
                    let offset = spec.get("offset")?.as_usize()?;
                    // Checked arithmetic throughout: a corrupt header must
                    // produce an error, not an overflow panic/wrap.
                    let nbytes = shape
                        .iter()
                        .try_fold(4usize, |acc, &d| acc.checked_mul(d))
                        .filter(|&b| b <= payload_len)
                        .ok_or_else(|| {
                            anyhow::anyhow!(
                                "{}: corrupt header: {name}[{i}] shape {shape:?} overflows payload",
                                path.display()
                            )
                        })?;
                    if offset.checked_add(nbytes).map_or(true, |end| end > payload_len) {
                        bail!(
                            "{}: truncated: {name}[{i}] needs {nbytes} bytes at payload \
                             offset {offset}, but only {payload_len} payload bytes exist",
                            path.display()
                        );
                    }
                    r.seek(SeekFrom::Start(payload_base + offset as u64))?;
                    scratch.resize(nbytes, 0);
                    r.read_exact(&mut scratch).with_context(|| {
                        format!(
                            "{}: truncated mid-read: {name}[{i}] ({nbytes} bytes at \
                             payload offset {offset})",
                            path.display()
                        )
                    })?;
                    Tensor::from_le_bytes(&shape, dtype, &scratch)
                })
                .collect()
        };

        Ok(Checkpoint {
            step: header.get("step")?.as_i64()? as u64,
            params: read_group("params")?,
            m: read_group("m")?,
            v: read_group("v")?,
        })
    }
}

/// Checkpoint retention: among `variant`'s checkpoints in `dir` at or below
/// `newest_step` (the step the caller just saved — filenames encode it as
/// `<variant>-step<N>.ckpt`), keep only the newest `keep` and delete the
/// rest. Returns the deleted paths. `keep` is clamped to at least 1 —
/// retention never deletes the newest checkpoint. Files with a step ABOVE
/// `newest_step` are foreign (stale leftovers of a longer previous run in
/// the same directory): they are never deleted and never counted toward
/// `keep`, so a shorter re-run cannot prune away its own fresh checkpoints
/// in favor of another run's. Files that don't match the naming scheme
/// (other variants, in-flight `.tmp` files) are never touched either.
pub fn prune_checkpoints(
    dir: &Path,
    variant: &str,
    keep: usize,
    newest_step: u64,
) -> Result<Vec<std::path::PathBuf>> {
    let prefix = format!("{variant}-step");
    let mut found: Vec<(u64, std::path::PathBuf)> = Vec::new();
    for entry in std::fs::read_dir(dir)
        .with_context(|| format!("listing checkpoint dir {}", dir.display()))?
    {
        let entry = entry?;
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        let Some(rest) = name.strip_prefix(&prefix) else { continue };
        let Some(step_str) = rest.strip_suffix(".ckpt") else { continue };
        let Ok(step) = step_str.parse::<u64>() else { continue };
        if step > newest_step {
            continue; // foreign: a previous, longer run's checkpoint
        }
        found.push((step, entry.path()));
    }
    found.sort_by_key(|(step, _)| *step);
    let keep = keep.max(1);
    if found.len() <= keep {
        return Ok(Vec::new());
    }
    let cut = found.len() - keep;
    let mut removed = Vec::with_capacity(cut);
    for (_, path) in found.drain(..cut) {
        match std::fs::remove_file(&path) {
            Ok(()) => removed.push(path),
            // Already gone (operator cleanup or a concurrent pruner racing
            // between read_dir and here): the goal state is reached.
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
            Err(e) => {
                return Err(e)
                    .with_context(|| format!("pruning checkpoint {}", path.display()));
            }
        }
    }
    Ok(removed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::rng::Rng;

    fn rand_tensors(rng: &mut Rng, n: usize) -> Vec<Tensor> {
        (0..n)
            .map(|_| {
                let d0 = 1 + rng.below(5) as usize;
                let d1 = 1 + rng.below(7) as usize;
                let data: Vec<f32> =
                    (0..d0 * d1).map(|_| rng.next_f64() as f32 - 0.5).collect();
                Tensor::f32(&[d0, d1], data)
            })
            .collect()
    }

    fn tmp_path(dir: &str, file: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(dir);
        std::fs::create_dir_all(&d).unwrap();
        d.join(file)
    }

    #[test]
    fn roundtrip() {
        let mut rng = Rng::new(1);
        let ck = Checkpoint {
            step: 123,
            params: rand_tensors(&mut rng, 5),
            m: rand_tensors(&mut rng, 5),
            v: rand_tensors(&mut rng, 5),
        };
        let path = tmp_path("rom_ckpt_test", "test.ckpt");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.step, 123);
        assert_eq!(back.params.len(), 5);
        for (a, b) in ck.params.iter().zip(back.params.iter()) {
            assert_eq!(a.shape, b.shape);
            assert_eq!(a.as_f32().unwrap(), b.as_f32().unwrap());
        }
        for (a, b) in ck.v.iter().zip(back.v.iter()) {
            assert_eq!(a.as_f32().unwrap(), b.as_f32().unwrap());
        }
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn rejects_non_checkpoint() {
        let path = tmp_path("rom_ckpt_test2", "junk.ckpt");
        std::fs::write(&path, b"not a checkpoint at all").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn i32_tensors_roundtrip() {
        let ck = Checkpoint {
            step: 1,
            params: vec![Tensor::i32(&[3], vec![1, -5, 7])],
            m: vec![],
            v: vec![],
        };
        let path = tmp_path("rom_ckpt_test3", "i32.ckpt");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.params[0].as_i32().unwrap(), &[1, -5, 7]);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn zero_leaf_roundtrip() {
        // A checkpoint with no leaves at all must survive the streaming
        // writer (empty payload region, header only).
        let ck = Checkpoint { step: 9, params: vec![], m: vec![], v: vec![] };
        let path = tmp_path("rom_ckpt_test4", "empty.ckpt");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.step, 9);
        assert!(back.params.is_empty() && back.m.is_empty() && back.v.is_empty());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn truncated_payload_is_an_error() {
        let mut rng = Rng::new(2);
        let ck = Checkpoint {
            step: 5,
            params: rand_tensors(&mut rng, 3),
            m: vec![],
            v: vec![],
        };
        let path = tmp_path("rom_ckpt_test5", "trunc.ckpt");
        ck.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        // Chop the last 5 payload bytes: load must fail with a clear error,
        // not return short tensors.
        std::fs::write(&path, &bytes[..bytes.len() - 5]).unwrap();
        let err = Checkpoint::load(&path).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("truncated"), "got: {err:#}");
        // The error must say WHICH file, WHICH leaf, and WHERE — an operator
        // staring at a failed restore needs more than "truncated".
        assert!(msg.contains("trunc.ckpt"), "no path in: {err:#}");
        assert!(msg.contains("params[2]"), "no leaf in: {err:#}");
        assert!(msg.contains("offset"), "no offset in: {err:#}");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn overflowing_header_shape_is_an_error() {
        // A header whose shape product overflows usize (or exceeds the
        // payload) must load as Err, not panic or fabricate a tensor.
        let header = r#"{"step":1,"params":[{"shape":[4611686018427387904,4],"dtype":"float32","offset":0}],"m":[],"v":[]}"#;
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&(header.len() as u64).to_le_bytes());
        bytes.extend_from_slice(header.as_bytes());
        let path = tmp_path("rom_ckpt_test7", "overflow.ckpt");
        std::fs::write(&path, &bytes).unwrap();
        let err = Checkpoint::load(&path).unwrap_err();
        assert!(err.to_string().contains("overflows payload"), "got: {err:#}");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn prune_keeps_newest_n() {
        let dir = std::env::temp_dir().join("rom_ckpt_prune1");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        for step in [2u64, 4, 10, 6, 8] {
            std::fs::write(dir.join(format!("tiny-step{step}.ckpt")), b"x").unwrap();
        }
        // Non-matching files must survive: other variant, tmp, junk.
        std::fs::write(dir.join("other-step1.ckpt"), b"x").unwrap();
        std::fs::write(dir.join("tiny-step3.tmp"), b"x").unwrap();
        std::fs::write(dir.join("tiny-stepnotanumber.ckpt"), b"x").unwrap();

        let removed = prune_checkpoints(&dir, "tiny", 2, 10).unwrap();
        let mut removed_names: Vec<String> = removed
            .iter()
            .map(|p| p.file_name().unwrap().to_string_lossy().into_owned())
            .collect();
        removed_names.sort();
        assert_eq!(removed_names, vec!["tiny-step2.ckpt", "tiny-step4.ckpt", "tiny-step6.ckpt"]);
        for survivor in ["tiny-step8.ckpt", "tiny-step10.ckpt", "other-step1.ckpt",
                         "tiny-step3.tmp", "tiny-stepnotanumber.ckpt"] {
            assert!(dir.join(survivor).exists(), "{survivor} was wrongly pruned");
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn prune_ignores_stale_higher_step_checkpoints() {
        // A shorter re-run in a directory holding a longer previous run's
        // checkpoints must never prune its own fresh saves in their favor.
        let dir = std::env::temp_dir().join("rom_ckpt_prune3");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        for step in [40u64, 50, 400, 500] {
            std::fs::write(dir.join(format!("v-step{step}.ckpt")), b"x").unwrap();
        }
        // Current run just saved step 50 with keep=1: only step 40 (this
        // run's older save) goes; steps 400/500 are foreign and survive.
        let removed = prune_checkpoints(&dir, "v", 1, 50).unwrap();
        assert_eq!(removed.len(), 1);
        assert!(removed[0].ends_with("v-step40.ckpt"));
        for survivor in ["v-step50.ckpt", "v-step400.ckpt", "v-step500.ckpt"] {
            assert!(dir.join(survivor).exists(), "{survivor} was wrongly pruned");
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn prune_noop_below_threshold_and_clamps_keep() {
        let dir = std::env::temp_dir().join("rom_ckpt_prune2");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("v-step1.ckpt"), b"x").unwrap();
        std::fs::write(dir.join("v-step2.ckpt"), b"x").unwrap();
        // keep >= count: nothing removed.
        assert!(prune_checkpoints(&dir, "v", 2, 2).unwrap().is_empty());
        assert!(prune_checkpoints(&dir, "v", 5, 2).unwrap().is_empty());
        // keep = 0 clamps to 1: the newest checkpoint always survives.
        let removed = prune_checkpoints(&dir, "v", 0, 2).unwrap();
        assert_eq!(removed.len(), 1);
        assert!(dir.join("v-step2.ckpt").exists());
        assert!(!dir.join("v-step1.ckpt").exists());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn corrupt_header_length_is_an_error() {
        let mut rng = Rng::new(3);
        let ck = Checkpoint {
            step: 5,
            params: rand_tensors(&mut rng, 1),
            m: vec![],
            v: vec![],
        };
        let path = tmp_path("rom_ckpt_test6", "hdr.ckpt");
        ck.save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        // Overwrite the u64 header-length prefix with an absurd value: load
        // must reject it up front instead of attempting a giant allocation.
        bytes[8..16].copy_from_slice(&u64::MAX.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err = Checkpoint::load(&path).unwrap_err();
        assert!(err.to_string().contains("corrupt header length"), "got: {err:#}");
        std::fs::remove_file(&path).unwrap();
    }
}
