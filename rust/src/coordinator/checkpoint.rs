//! Binary checkpoint format for (params, m, v, step) state.
//!
//! Layout: magic "ROMCKPT1" | u64 header_len | header JSON (leaf names,
//! shapes, dtypes, step, offsets) | raw little-endian tensor payloads.
//! JSON-in-header keeps the format self-describing; raw payloads keep a
//! multi-MB state fast to write/restore (a pure-JSON checkpoint would be
//! ~10x larger and slower to parse).

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::runtime::tensor::{DType, Tensor};
use crate::substrate::json::Json;

const MAGIC: &[u8; 8] = b"ROMCKPT1";

pub struct Checkpoint {
    pub step: u64,
    pub params: Vec<Tensor>,
    pub m: Vec<Tensor>,
    pub v: Vec<Tensor>,
}

impl Checkpoint {
    pub fn save(&self, path: &Path) -> Result<()> {
        let groups: [(&str, &Vec<Tensor>); 3] =
            [("params", &self.params), ("m", &self.m), ("v", &self.v)];
        let mut header_groups = Vec::new();
        let mut payload: Vec<u8> = Vec::new();
        for (name, tensors) in groups {
            let mut specs = Vec::new();
            for t in tensors.iter() {
                let offset = payload.len();
                match &t.data {
                    crate::runtime::tensor::TensorData::F32(v) => {
                        for x in v {
                            payload.extend_from_slice(&x.to_le_bytes());
                        }
                    }
                    crate::runtime::tensor::TensorData::I32(v) => {
                        for x in v {
                            payload.extend_from_slice(&x.to_le_bytes());
                        }
                    }
                }
                specs.push(Json::obj(vec![
                    ("shape", Json::arr_usize(&t.shape)),
                    ("dtype", Json::str(t.dtype().name())),
                    ("offset", Json::num(offset as f64)),
                ]));
            }
            header_groups.push((name, Json::Arr(specs)));
        }
        let header = Json::obj(vec![
            ("step", Json::num(self.step as f64)),
            ("params", header_groups[0].1.clone()),
            ("m", header_groups[1].1.clone()),
            ("v", header_groups[2].1.clone()),
        ])
        .to_string();

        let tmp = path.with_extension("tmp");
        {
            let mut f = std::fs::File::create(&tmp)
                .with_context(|| format!("creating {}", tmp.display()))?;
            f.write_all(MAGIC)?;
            f.write_all(&(header.len() as u64).to_le_bytes())?;
            f.write_all(header.as_bytes())?;
            f.write_all(&payload)?;
            f.sync_all()?;
        }
        std::fs::rename(&tmp, path)?; // atomic publish
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?;
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("{} is not a ROM checkpoint", path.display());
        }
        let mut len8 = [0u8; 8];
        f.read_exact(&mut len8)?;
        let hlen = u64::from_le_bytes(len8) as usize;
        let mut hbuf = vec![0u8; hlen];
        f.read_exact(&mut hbuf)?;
        let header = Json::parse(std::str::from_utf8(&hbuf)?)?;
        let mut payload = Vec::new();
        f.read_to_end(&mut payload)?;

        let read_group = |name: &str| -> Result<Vec<Tensor>> {
            header
                .get(name)?
                .as_arr()?
                .iter()
                .map(|spec| {
                    let shape: Vec<usize> = spec
                        .get("shape")?
                        .as_arr()?
                        .iter()
                        .map(|v| v.as_usize())
                        .collect::<Result<_, _>>()?;
                    let dtype = DType::from_str(spec.get("dtype")?.as_str()?)?;
                    let offset = spec.get("offset")?.as_usize()?;
                    let n: usize = shape.iter().product();
                    let bytes = payload
                        .get(offset..offset + 4 * n)
                        .ok_or_else(|| anyhow::anyhow!("checkpoint payload truncated"))?;
                    Ok(match dtype {
                        DType::F32 => Tensor::f32(
                            &shape,
                            bytes
                                .chunks_exact(4)
                                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                                .collect(),
                        ),
                        DType::I32 => Tensor::i32(
                            &shape,
                            bytes
                                .chunks_exact(4)
                                .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                                .collect(),
                        ),
                    })
                })
                .collect()
        };

        Ok(Checkpoint {
            step: header.get("step")?.as_i64()? as u64,
            params: read_group("params")?,
            m: read_group("m")?,
            v: read_group("v")?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::rng::Rng;

    fn rand_tensors(rng: &mut Rng, n: usize) -> Vec<Tensor> {
        (0..n)
            .map(|_| {
                let d0 = 1 + rng.below(5) as usize;
                let d1 = 1 + rng.below(7) as usize;
                let data: Vec<f32> =
                    (0..d0 * d1).map(|_| rng.next_f64() as f32 - 0.5).collect();
                Tensor::f32(&[d0, d1], data)
            })
            .collect()
    }

    #[test]
    fn roundtrip() {
        let mut rng = Rng::new(1);
        let ck = Checkpoint {
            step: 123,
            params: rand_tensors(&mut rng, 5),
            m: rand_tensors(&mut rng, 5),
            v: rand_tensors(&mut rng, 5),
        };
        let dir = std::env::temp_dir().join("rom_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("test.ckpt");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.step, 123);
        assert_eq!(back.params.len(), 5);
        for (a, b) in ck.params.iter().zip(back.params.iter()) {
            assert_eq!(a.shape, b.shape);
            assert_eq!(a.as_f32().unwrap(), b.as_f32().unwrap());
        }
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn rejects_non_checkpoint() {
        let dir = std::env::temp_dir().join("rom_ckpt_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("junk.ckpt");
        std::fs::write(&path, b"not a checkpoint at all").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn i32_tensors_roundtrip() {
        let ck = Checkpoint {
            step: 1,
            params: vec![Tensor::i32(&[3], vec![1, -5, 7])],
            m: vec![],
            v: vec![],
        };
        let dir = std::env::temp_dir().join("rom_ckpt_test3");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("i32.ckpt");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.params[0].as_i32().unwrap(), &[1, -5, 7]);
        std::fs::remove_file(&path).unwrap();
    }
}
