//! Multi-length perplexity evaluation (Figures 3/4, Tables 1/3/7-10).
//!
//! Held-out streams come from the same corpus generator with a disjoint seed
//! space; PPL(ctx) = exp(sum NLL / tokens) over `n_seq` sequences per length.
//!
//! Every sequence is independent, so the host-side work — Markov stream
//! generation and (1, L) tensor assembly — fans out across eval workers
//! (scoped threads, one chunk per core). Device execution stays on the
//! caller's thread: PJRT handles are thread-affine until the FFI wrapper
//! declares `Send` (see `runtime::artifact` module docs), and a single
//! serial pass over pre-assembled sequences keeps the NLL accumulation order
//! — and therefore the reported PPL, bit for bit — identical to the fully
//! serial path. Variant-level parallelism (the experiment scheduler) stacks
//! on top of this.

use anyhow::Result;

use crate::data::corpus::Corpus;
use crate::runtime::session::Session;
use crate::runtime::tensor::Tensor;

/// One pre-assembled held-out sequence: context length + (1, ctx) pair.
struct EvalSeq {
    ctx: usize,
    tokens: Tensor,
    targets: Tensor,
}

/// Build the held-out sequence `i` for context length `ctx`. The stream seed
/// lives in a disjoint space from training streams (train streams use small
/// seeds) and depends only on (seed, i), so the same streams are reused
/// across lengths — the length extrapolation comparison (Fig 4) evaluates
/// the same text at every ctx.
fn held_out_seq(corpus: &Corpus, seed: u64, ctx: usize, i: u64) -> EvalSeq {
    let stream =
        corpus.generate(0xE7A1_0000u64.wrapping_add(seed).wrapping_add(i), ctx + 1);
    EvalSeq {
        ctx,
        tokens: Tensor::i32(&[1, ctx], stream[..ctx].to_vec()),
        targets: Tensor::i32(&[1, ctx], stream[1..ctx + 1].to_vec()),
    }
}

/// Below this many total tokens of generation, thread spawn overhead rivals
/// the Markov sampling itself: the periodic in-training cadence (n_seq=4)
/// stays serial, while the final sweep (n_seq=8 over all lens) and anything
/// larger fans out.
const PARALLEL_ASSEMBLY_MIN_TOKENS: usize = 4096;

/// Assemble all (ctx, i) sequences, fanning the host-side generation out
/// over scoped worker threads when the work is large enough to pay for
/// them. Output order is exactly the serial iteration order (lens-major,
/// then sequence index).
fn assemble_seqs(corpus: &Corpus, seed: u64, n_seq: usize, lens: &[usize]) -> Vec<EvalSeq> {
    let items: Vec<(usize, u64)> = lens
        .iter()
        .flat_map(|&ctx| (0..n_seq as u64).map(move |i| (ctx, i)))
        .collect();
    let total_tokens: usize = items.iter().map(|&(ctx, _)| ctx + 1).sum();
    // Cap the fan-out: 8 generator threads saturate the assembly long before
    // a big box's core count, and under `--jobs N` every scheduler worker
    // runs its own evals — unbounded per-eval spawning would multiply.
    let workers = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(8)
        .min(items.len().max(1));
    if workers <= 1 || items.len() <= 1 || total_tokens < PARALLEL_ASSEMBLY_MIN_TOKENS {
        return items.iter().map(|&(ctx, i)| held_out_seq(corpus, seed, ctx, i)).collect();
    }
    let chunk = items.len().div_ceil(workers);
    let mut out: Vec<Option<EvalSeq>> = items.iter().map(|_| None).collect();
    std::thread::scope(|s| {
        for (chunk_items, chunk_out) in items.chunks(chunk).zip(out.chunks_mut(chunk)) {
            s.spawn(move || {
                for (slot, &(ctx, i)) in chunk_out.iter_mut().zip(chunk_items.iter()) {
                    *slot = Some(held_out_seq(corpus, seed, ctx, i));
                }
            });
        }
    });
    out.into_iter().map(|s| s.expect("eval worker left a hole")).collect()
}

/// PPL at every eval length baked into the bundle. Host assembly is
/// parallel; the result is bit-identical to evaluating serially.
pub fn eval_ppl_sweep(
    sess: &Session,
    corpus: &Corpus,
    seed: u64,
    n_seq: usize,
) -> Result<Vec<(usize, f64)>> {
    let lens = sess.bundle.manifest.eval_lens.clone();
    let seqs = assemble_seqs(corpus, seed, n_seq, &lens);
    // Row k consumes exactly its own n_seq assembled sequences (lens-major
    // layout) — indexing by range rather than matching on ctx value keeps
    // the old per-length loop's semantics even if a manifest repeats a
    // length in eval_lens.
    let mut out = Vec::with_capacity(lens.len());
    for (k, &ctx) in lens.iter().enumerate() {
        out.push((ctx, ppl_over(sess, seqs[k * n_seq..(k + 1) * n_seq].iter())?));
    }
    Ok(out)
}

/// PPL at one context length.
pub fn eval_ppl(
    sess: &Session,
    corpus: &Corpus,
    seed: u64,
    n_seq: usize,
    ctx: usize,
) -> Result<f64> {
    let seqs = assemble_seqs(corpus, seed, n_seq, &[ctx]);
    ppl_over(sess, seqs.iter())
}

/// Serial device pass: summed NLL / tokens over the given sequences, in
/// iteration order (the accumulation order IS the determinism contract).
fn ppl_over<'a>(sess: &Session, seqs: impl Iterator<Item = &'a EvalSeq>) -> Result<f64> {
    let mut nll_sum = 0.0;
    let mut count = 0.0;
    for seq in seqs {
        let (nll, c) = sess.eval(seq.ctx, &seq.tokens, &seq.targets)?;
        nll_sum += nll;
        count += c;
    }
    Ok((nll_sum / count).exp())
}
