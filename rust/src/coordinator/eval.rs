//! Multi-length perplexity evaluation (Figures 3/4, Tables 1/3/7-10).
//!
//! Held-out streams come from the same corpus generator with a disjoint seed
//! space; PPL(ctx) = exp(sum NLL / tokens) over `n_seq` sequences per length.

use anyhow::Result;

use crate::data::corpus::Corpus;
use crate::runtime::session::Session;
use crate::runtime::tensor::Tensor;

/// PPL at every eval length baked into the bundle.
pub fn eval_ppl_sweep(
    sess: &Session,
    corpus: &Corpus,
    seed: u64,
    n_seq: usize,
) -> Result<Vec<(usize, f64)>> {
    let lens = sess.bundle.manifest.eval_lens.clone();
    lens.into_iter()
        .map(|ctx| Ok((ctx, eval_ppl(sess, corpus, seed, n_seq, ctx)?)))
        .collect()
}

/// PPL at one context length.
pub fn eval_ppl(
    sess: &Session,
    corpus: &Corpus,
    seed: u64,
    n_seq: usize,
    ctx: usize,
) -> Result<f64> {
    let mut nll_sum = 0.0;
    let mut count = 0.0;
    for i in 0..n_seq {
        // Disjoint held-out stream space (train streams use small seeds).
        let stream = corpus.generate(0xE7A1_0000u64.wrapping_add(seed).wrapping_add(i as u64), ctx + 1);
        let tokens = Tensor::i32(&[1, ctx], stream[..ctx].to_vec());
        let targets = Tensor::i32(&[1, ctx], stream[1..ctx + 1].to_vec());
        let (nll, c) = sess.eval(ctx, &tokens, &targets)?;
        nll_sum += nll;
        count += c;
    }
    Ok((nll_sum / count).exp())
}
