//! Expert-load monitor: tracks per-router dispatch fractions across steps and
//! derives the balance diagnostics behind Table 6 ("RoM balances naturally
//! without an aux loss"): max/mean load ratio, load entropy, dead experts.

#[derive(Debug, Clone)]
pub struct LoadSnapshot {
    /// Router-major (R x E) dispatch fractions for one step.
    pub load: Vec<f32>,
    pub routers: usize,
    pub experts: usize,
}

#[derive(Debug, Clone, Default)]
pub struct BalanceReport {
    /// max_e load / (1/E), averaged over routers (1.0 = perfectly balanced).
    pub max_over_uniform: f64,
    /// Mean normalized entropy of the load distribution (1.0 = uniform).
    pub norm_entropy: f64,
    /// Fraction of (router, expert) pairs receiving < 1% of uniform share.
    pub dead_fraction: f64,
}

pub struct ExpertMonitor {
    routers: usize,
    experts: usize,
    /// EMA of per-(router, expert) load.
    ema: Vec<f64>,
    ema_decay: f64,
    steps: u64,
}

impl ExpertMonitor {
    pub fn new(routers: usize, experts: usize) -> ExpertMonitor {
        ExpertMonitor {
            routers,
            experts,
            ema: vec![1.0 / experts.max(1) as f64; routers * experts],
            ema_decay: 0.95,
            steps: 0,
        }
    }

    pub fn observe(&mut self, load: &[f32]) {
        assert_eq!(load.len(), self.routers * self.experts, "load shape mismatch");
        self.steps += 1;
        if self.steps == 1 {
            // Seed the EMA from the first observation rather than blending it
            // into the uniform prior: telemetry is sampled (every log_every
            // steps), so with few observations a prior-seeded EMA would
            // report near-uniform balance no matter how collapsed the real
            // dispatch is.
            for (e, &l) in self.ema.iter_mut().zip(load.iter()) {
                *e = l as f64;
            }
            return;
        }
        for (e, &l) in self.ema.iter_mut().zip(load.iter()) {
            *e = self.ema_decay * *e + (1.0 - self.ema_decay) * l as f64;
        }
    }

    pub fn steps(&self) -> u64 {
        self.steps
    }

    pub fn report(&self) -> BalanceReport {
        if self.experts <= 1 {
            return BalanceReport { max_over_uniform: 1.0, norm_entropy: 1.0, dead_fraction: 0.0 };
        }
        let uniform = 1.0 / self.experts as f64;
        let mut max_ratio = 0.0;
        let mut entropy_sum = 0.0;
        let mut dead = 0usize;
        for r in 0..self.routers {
            let row = &self.ema[r * self.experts..(r + 1) * self.experts];
            let total: f64 = row.iter().sum();
            let norm: Vec<f64> = row.iter().map(|&x| x / total.max(1e-12)).collect();
            let mx = norm.iter().cloned().fold(0.0, f64::max);
            max_ratio += mx / uniform;
            let h: f64 = norm
                .iter()
                .filter(|&&p| p > 0.0)
                .map(|&p| -p * p.ln())
                .sum();
            entropy_sum += h / (self.experts as f64).ln();
            dead += norm.iter().filter(|&&p| p < 0.01 * uniform).count();
        }
        BalanceReport {
            max_over_uniform: max_ratio / self.routers as f64,
            norm_entropy: entropy_sum / self.routers as f64,
            dead_fraction: dead as f64 / (self.routers * self.experts) as f64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_load_is_balanced() {
        let mut m = ExpertMonitor::new(2, 4);
        for _ in 0..50 {
            m.observe(&[0.25; 8]);
        }
        let r = m.report();
        assert!((r.max_over_uniform - 1.0).abs() < 1e-9);
        assert!((r.norm_entropy - 1.0).abs() < 1e-9);
        assert_eq!(r.dead_fraction, 0.0);
    }

    #[test]
    fn collapsed_load_is_flagged() {
        let mut m = ExpertMonitor::new(1, 4);
        for _ in 0..200 {
            m.observe(&[1.0, 0.0, 0.0, 0.0]);
        }
        let r = m.report();
        assert!(r.max_over_uniform > 3.5, "{r:?}");
        assert!(r.norm_entropy < 0.1, "{r:?}");
        assert!(r.dead_fraction > 0.5, "{r:?}");
    }

    #[test]
    fn ema_tracks_shift() {
        let mut m = ExpertMonitor::new(1, 2);
        for _ in 0..100 {
            m.observe(&[1.0, 0.0]);
        }
        for _ in 0..100 {
            m.observe(&[0.0, 1.0]);
        }
        let r = m.report();
        // After the shift the EMA should strongly favour expert 1.
        assert!(m.ema[1] > 0.9, "{:?}", m.ema);
        assert!(r.max_over_uniform > 1.8);
    }

    #[test]
    fn sparse_sampling_still_flags_collapse() {
        // Telemetry is decoded every log_every steps, so a run may observe
        // only a handful of loads; a collapsed router must still be flagged
        // (the EMA is seeded from the first observation, not a uniform prior).
        let mut m = ExpertMonitor::new(1, 4);
        for _ in 0..5 {
            m.observe(&[1.0, 0.0, 0.0, 0.0]);
        }
        let r = m.report();
        assert!(r.max_over_uniform > 3.5, "{r:?}");
        assert!(r.norm_entropy < 0.1, "{r:?}");
    }

    #[test]
    #[should_panic(expected = "load shape mismatch")]
    fn rejects_wrong_shape() {
        let mut m = ExpertMonitor::new(1, 4);
        m.observe(&[0.5, 0.5]);
    }
}
