//! Routing Mamba (RoM) reproduction — rust L3 coordinator.
//!
//! Architecture (DESIGN.md): python/jax+pallas author the model at build time
//! and AOT-lower it to HLO-text artifacts; this crate loads them via PJRT and
//! owns everything else — config, data pipeline, train loop, schedules,
//! telemetry, eval, checkpoints, experiments. Python never runs at runtime.
pub mod analysis;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod runtime;
pub mod substrate;
