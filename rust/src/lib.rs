//! Routing Mamba (RoM) reproduction — rust L3 coordinator.
//!
//! Architecture (DESIGN.md): python/jax+pallas author the model at build time
//! and AOT-lower it to HLO-text artifacts; this crate loads them via PJRT and
//! owns everything else — config, data pipeline, train loop, schedules,
//! telemetry, eval, checkpoints, experiments. Python never runs at runtime.
// `--cfg loom` (set via RUSTFLAGS, not a Cargo feature, so rustc's
// check-cfg tables don't know it) swaps `substrate::sync` to loom's
// model-checked primitives; `unknown_lints` covers toolchains predating
// the `unexpected_cfgs` lint.
#![allow(unknown_lints)]
#![allow(unexpected_cfgs)]
pub mod analysis;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod runtime;
pub mod substrate;
