//! Manifest contract checker: statically verifies that a python-emitted
//! `manifest.json` satisfies everything the rust runtime assumes when it
//! consumes the bundle blind (`runtime::artifact::Manifest::parse`,
//! `runtime::session`, `config::ModelCfg`).
//!
//! Checked invariants:
//!
//! * every field the rust side reads exists with the right type — counts
//!   must be *integer-valued* numbers, because `Json::as_usize` goes
//!   through `as f64 as usize` and would silently truncate `2.7` to `2`;
//! * flat param leaves are self-consistent: unique non-empty names, sane
//!   shapes, known dtypes, `num_param_leaves == len(params)`, and
//!   `analysis.total_params` equal to the exact sum of leaf elements
//!   (python/compile/analysis.py counts leaf-by-leaf, no rounding);
//! * the `model` section parses as `ModelCfg` and agrees with the
//!   top-level `name`/`batch_size`/`seq_len`/`eval_lens` duplicates;
//! * decode invariants: `decode` XOR `decode_unsupported` (non-null),
//!   `prefill_lens == eval_lens` and strictly increasing, state leaf 0 is
//!   the scalar i32 `pos`, every other leaf carries the decode batch as
//!   dim 0, KV-cache leaves appear iff the block layout has SWA blocks —
//!   and the whole flat state list must equal, leaf for leaf, the
//!   rust-side mirror of `python/compile/decode.py::state_spec`;
//! * `decode.kv_cap`: a full-attention layout (swa blocks with window 0)
//!   must declare an integer cache capacity equal to the `ModelCfg::kv_cap`
//!   derivation AND to the cache leaves' capacity dim; any other layout
//!   must leave it null/absent (the coordinator stops requests at the cap,
//!   so a wrong value means silent cache overwrites or spurious stops);
//!
//! Findings are anchored to the manifest's real file/line via a JSON-path
//! index built from the source text, so a mutated field is reported where
//! it sits, not as "somewhere in the file".

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::analysis::Finding;
use crate::config::ModelCfg;
use crate::substrate::json::{Json, JsonError};

/// One flat leaf as the checker sees it (shapes in u64 so a corrupt
/// manifest can't wrap a usize on 32-bit hosts).
#[derive(Debug, Clone, PartialEq, Eq)]
struct Leaf {
    name: String,
    shape: Vec<u64>,
    dtype: String,
}

impl Leaf {
    fn numel(&self) -> u64 {
        self.shape.iter().product()
    }

    fn describe(&self) -> String {
        format!("{} {:?} {}", self.name, self.shape, self.dtype)
    }
}

// ---------------------------------------------------------------------------
// JSON-path → line index
// ---------------------------------------------------------------------------

/// Walk already-validated JSON text and record the 1-based line of every
/// key/element, addressed as `decode.state[3].shape`. Lenient by design —
/// it only runs after `Json::parse_bytes` accepted the document.
fn key_lines(text: &str) -> BTreeMap<String, usize> {
    struct W<'a> {
        b: &'a [u8],
        i: usize,
        line: usize,
        out: BTreeMap<String, usize>,
    }
    impl W<'_> {
        fn ws(&mut self) {
            while self.i < self.b.len() {
                match self.b[self.i] {
                    b'\n' => {
                        self.line += 1;
                        self.i += 1;
                    }
                    b' ' | b'\t' | b'\r' => self.i += 1,
                    _ => break,
                }
            }
        }

        fn string(&mut self) -> String {
            let mut out = String::new();
            self.i += 1; // opening quote
            while self.i < self.b.len() {
                match self.b[self.i] {
                    b'"' => {
                        self.i += 1;
                        break;
                    }
                    b'\\' => {
                        // Escapes never occur in the key/name grammar this
                        // index serves; skip the pair without decoding.
                        self.i = (self.i + 2).min(self.b.len());
                        out.push('?');
                    }
                    c => {
                        if c == b'\n' {
                            self.line += 1;
                        }
                        out.push(c as char);
                        self.i += 1;
                    }
                }
            }
            out
        }

        fn value(&mut self, path: &str) {
            self.ws();
            if self.i >= self.b.len() {
                return;
            }
            self.out.entry(path.to_string()).or_insert(self.line);
            match self.b[self.i] {
                b'{' => {
                    self.i += 1;
                    loop {
                        self.ws();
                        if self.i >= self.b.len() {
                            return;
                        }
                        if self.b[self.i] == b'}' {
                            self.i += 1;
                            return;
                        }
                        if self.b[self.i] == b',' {
                            self.i += 1;
                            continue;
                        }
                        let key_line = self.line;
                        let key = self.string();
                        let child = if path.is_empty() {
                            key
                        } else {
                            format!("{path}.{key}")
                        };
                        self.out.entry(child.clone()).or_insert(key_line);
                        self.ws();
                        if self.i < self.b.len() && self.b[self.i] == b':' {
                            self.i += 1;
                        }
                        self.value(&child);
                    }
                }
                b'[' => {
                    self.i += 1;
                    let mut idx = 0usize;
                    loop {
                        self.ws();
                        if self.i >= self.b.len() {
                            return;
                        }
                        if self.b[self.i] == b']' {
                            self.i += 1;
                            return;
                        }
                        if self.b[self.i] == b',' {
                            self.i += 1;
                            continue;
                        }
                        self.value(&format!("{path}[{idx}]"));
                        idx += 1;
                    }
                }
                b'"' => {
                    self.string();
                }
                _ => {
                    // Scalar: consume until a delimiter.
                    while self.i < self.b.len()
                        && !matches!(self.b[self.i], b',' | b'}' | b']' | b'\n')
                    {
                        self.i += 1;
                    }
                }
            }
        }
    }

    let mut w = W { b: text.as_bytes(), i: 0, line: 1, out: BTreeMap::new() };
    w.value("");
    w.out
}

/// Line of `path`, falling back to the nearest recorded ancestor (a missing
/// key has no line of its own — anchor at its parent object).
fn line_of(lines: &BTreeMap<String, usize>, path: &str) -> usize {
    let mut p = path.to_string();
    loop {
        if let Some(&l) = lines.get(&p) {
            return l;
        }
        let cut = match (p.rfind('.'), p.rfind('[')) {
            (None, None) => return 1,
            (a, b) => a.max(b).expect("one side is Some"),
        };
        p.truncate(cut);
        if p.is_empty() {
            return 1;
        }
    }
}

// ---------------------------------------------------------------------------
// Checker plumbing
// ---------------------------------------------------------------------------

struct Checker<'a> {
    file: &'a str,
    lines: BTreeMap<String, usize>,
    out: Vec<Finding>,
}

impl Checker<'_> {
    fn fail(&mut self, rule: &'static str, path: &str, msg: impl std::fmt::Display) {
        let line = line_of(&self.lines, path);
        let at = if path.is_empty() { String::new() } else { format!("`{path}`: ") };
        self.out.push(Finding::new(self.file, line, rule, format!("{at}{msg}")));
    }
}

fn join_path(base: &str, key: &str) -> String {
    if base.is_empty() {
        key.to_string()
    } else {
        format!("{base}.{key}")
    }
}

/// Integer-valued JSON number (what `as_usize` can read without silent
/// truncation or sign wrap).
fn as_uint(j: &Json) -> Option<u64> {
    match j {
        Json::Num(n) if n.fract() == 0.0 && *n >= 0.0 && *n < 9.0e15 => Some(*n as u64),
        _ => None,
    }
}

fn field<'j>(c: &mut Checker, j: &'j Json, base: &str, key: &str) -> Option<&'j Json> {
    match j.as_obj().ok().and_then(|o| o.get(key)) {
        Some(v) => Some(v),
        None => {
            c.fail(
                "contract/field",
                &join_path(base, key),
                "required field missing (the rust loader reads it)",
            );
            None
        }
    }
}

fn uint_field(c: &mut Checker, j: &Json, base: &str, key: &str, min: u64) -> Option<u64> {
    let v = field(c, j, base, key)?;
    let path = join_path(base, key);
    match as_uint(v) {
        Some(n) if n >= min => Some(n),
        Some(n) => {
            c.fail("contract/field", &path, format!("must be >= {min}, got {n}"));
            None
        }
        None => {
            c.fail(
                "contract/field",
                &path,
                format!(
                    "must be an integer-valued number ({} found; Json::as_usize \
                     would silently truncate)",
                    v.kind()
                ),
            );
            None
        }
    }
}

fn str_field(c: &mut Checker, j: &Json, base: &str, key: &str) -> Option<String> {
    let v = field(c, j, base, key)?;
    let path = join_path(base, key);
    match v.as_str() {
        Ok(s) if !s.is_empty() => Some(s.to_string()),
        Ok(_) => {
            c.fail("contract/field", &path, "must be a non-empty string");
            None
        }
        Err(_) => {
            c.fail("contract/field", &path, format!("must be a string, got {}", v.kind()));
            None
        }
    }
}

/// Array of integer-valued numbers, each >= `min`; per-element findings.
fn uint_list(c: &mut Checker, j: &Json, path: &str, min: u64) -> Option<Vec<u64>> {
    let arr = match j.as_arr() {
        Ok(a) => a,
        Err(_) => {
            c.fail("contract/field", path, format!("must be an array, got {}", j.kind()));
            return None;
        }
    };
    let mut out = Vec::with_capacity(arr.len());
    let mut ok = true;
    for (i, v) in arr.iter().enumerate() {
        match as_uint(v) {
            Some(n) if n >= min => out.push(n),
            _ => {
                c.fail(
                    "contract/field",
                    &format!("{path}[{i}]"),
                    format!("must be an integer >= {min}"),
                );
                ok = false;
            }
        }
    }
    ok.then_some(out)
}

/// Parse a `[{name, shape, dtype}, ...]` leaf array (params or decode
/// state), mirroring `runtime::artifact::parse_specs` but collecting
/// findings instead of bailing on the first defect.
fn leaf_list(c: &mut Checker, j: &Json, path: &str, rule: &'static str) -> Option<Vec<Leaf>> {
    let arr = match j.as_arr() {
        Ok(a) => a,
        Err(_) => {
            c.fail(rule, path, format!("must be an array, got {}", j.kind()));
            return None;
        }
    };
    let mut out = Vec::with_capacity(arr.len());
    let mut ok = true;
    for (i, p) in arr.iter().enumerate() {
        let base = format!("{path}[{i}]");
        let name = str_field(c, p, &base, "name");
        let shape = field(c, p, &base, "shape")
            .and_then(|s| uint_list(c, s, &format!("{base}.shape"), 1));
        let dtype = str_field(c, p, &base, "dtype");
        if let Some(d) = &dtype {
            if d != "float32" && d != "int32" {
                c.fail(
                    rule,
                    &format!("{base}.dtype"),
                    format!("unknown dtype {d:?} (rust DType::from_str knows float32/int32)"),
                );
                ok = false;
            }
        }
        match (name, shape, dtype) {
            (Some(name), Some(shape), Some(dtype)) => out.push(Leaf { name, shape, dtype }),
            _ => ok = false,
        }
    }
    if !ok {
        return None;
    }
    let mut seen = std::collections::BTreeSet::new();
    for (i, l) in out.iter().enumerate() {
        if !seen.insert(l.name.clone()) {
            c.fail(
                rule,
                &format!("{path}[{i}].name"),
                format!("duplicate leaf name {:?} (flat order is the calling convention)", l.name),
            );
        }
    }
    Some(out)
}

// ---------------------------------------------------------------------------
// The state-spec mirror
// ---------------------------------------------------------------------------

/// Rust mirror of `python/compile/decode.py::state_spec`: the exact flat
/// recurrent-state layout the emitter bakes into `prefill_L{L}` /
/// `decode_step` for a model config, with batch dim `b`.
fn expected_state(cfg: &ModelCfg, b: u64) -> Result<Vec<Leaf>, String> {
    let layout = cfg.block_layout().map_err(|e| e.to_string())?;
    let d = cfg.d_model as u64;
    let di = cfg.d_inner() as u64;
    let n = cfg.d_state as u64;
    let k = cfg.conv_kernel as u64;
    let h = cfg.n_heads as u64;
    let w = cfg.window as u64;
    if k == 0 {
        return Err("conv_kernel must be >= 1".into());
    }
    if h == 0 || di % h != 0 {
        return Err(format!("n_heads {h} must divide d_inner {di}"));
    }
    let mut out =
        vec![Leaf { name: "pos".into(), shape: vec![], dtype: "int32".into() }];
    let mut add = |i: usize, suffix: &str, shape: Vec<u64>| {
        out.push(Leaf {
            name: format!("blocks.{i}.{suffix}"),
            shape,
            dtype: "float32".into(),
        });
    };
    for (i, kind) in layout.iter().enumerate() {
        match *kind {
            "mamba" => {
                add(i, "conv", vec![b, k - 1, di]);
                add(i, "ssm", vec![b, di, n]);
            }
            "mamba2" => {
                add(i, "conv", vec![b, k - 1, di]);
                add(i, "ssd", vec![b, h, di / h, n]);
            }
            "gdn" => {
                add(i, "conv", vec![b, k - 1, di]);
                add(i, "delta", vec![b, h, di / h, di / h]);
            }
            "swa" => {
                // window > 0: rolling cache of capacity `window`; window 0:
                // full attention on a capped position-indexed cache of
                // capacity kv_cap (mirrors decode.py::state_spec).
                let cap = if w > 0 { w } else { cfg.kv_cap() as u64 };
                add(i, "k_cache", vec![b, cap, d]);
                add(i, "v_cache", vec![b, cap, d]);
            }
            "mlp" => {} // stateless
            other => return Err(format!("unknown block kind {other:?}")),
        }
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// The checks
// ---------------------------------------------------------------------------

fn check_root(c: &mut Checker, j: &Json) {
    if j.as_obj().is_err() {
        c.fail("contract/parse", "", format!("top level must be an object, got {}", j.kind()));
        return;
    }

    let name = str_field(c, j, "", "name");
    let batch_size = uint_field(c, j, "", "batch_size", 1);
    let seq_len = uint_field(c, j, "", "seq_len", 1);
    uint_field(c, j, "", "micro_batch", 0);
    uint_field(c, j, "", "num_routers", 0);
    uint_field(c, j, "", "num_experts", 1);

    let eval_lens = field(c, j, "", "eval_lens")
        .and_then(|v| uint_list(c, v, "eval_lens", 1))
        .and_then(|lens| {
            if lens.is_empty() {
                c.fail("contract/field", "eval_lens", "must be non-empty");
                return None;
            }
            if !lens.windows(2).all(|p| p[0] < p[1]) {
                c.fail(
                    "contract/field",
                    "eval_lens",
                    format!("must be strictly increasing, got {lens:?}"),
                );
                return None;
            }
            Some(lens)
        });

    // Param leaves + the exact-count invariant.
    let params = field(c, j, "", "params")
        .and_then(|v| leaf_list(c, v, "params", "contract/params"));
    if let Some(params) = &params {
        if params.is_empty() {
            c.fail("contract/params", "params", "must list at least one leaf");
        }
        if let Some(n) = uint_field(c, j, "", "num_param_leaves", 0) {
            if n != params.len() as u64 {
                c.fail(
                    "contract/params",
                    "num_param_leaves",
                    format!("says {n} leaves but params lists {}", params.len()),
                );
            }
        }
    }

    // Analytic accounting.
    if let Some(a) = field(c, j, "", "analysis") {
        let total = uint_field(c, a, "analysis", "total_params", 1);
        let active = uint_field(c, a, "analysis", "active_params", 1);
        if let (Some(t), Some(act)) = (total, active) {
            if act > t {
                c.fail(
                    "contract/analysis",
                    "analysis.active_params",
                    format!("active {act} exceeds total {t}"),
                );
            }
        }
        match field(c, a, "analysis", "fwd_flops_per_token").map(Json::as_f64) {
            Some(Ok(f)) if f.is_finite() && f > 0.0 => {}
            Some(Ok(f)) => c.fail(
                "contract/analysis",
                "analysis.fwd_flops_per_token",
                format!("must be a positive finite number, got {f}"),
            ),
            Some(Err(_)) => c.fail(
                "contract/analysis",
                "analysis.fwd_flops_per_token",
                "must be a number",
            ),
            None => {}
        }
        if let (Some(t), Some(params)) = (total, &params) {
            let sum: u64 = params.iter().map(Leaf::numel).sum();
            if sum != t {
                c.fail(
                    "contract/analysis",
                    "analysis.total_params",
                    format!(
                        "claims {t} but the param leaves sum to {sum} \
                         (python counts leaf elements exactly — any gap means \
                         the manifest and the lowered params disagree)"
                    ),
                );
            }
        }
    }

    // Model section: must parse as ModelCfg and agree with the top-level
    // duplicates the rust loader reads directly.
    let cfg = match field(c, j, "", "model") {
        Some(m) => match ModelCfg::parse(m) {
            Ok(cfg) => Some(cfg),
            Err(e) => {
                c.fail(
                    "contract/field",
                    "model",
                    format!("does not parse as ModelCfg: {e:#}"),
                );
                None
            }
        },
        None => None,
    };
    if let Some(cfg) = &cfg {
        if cfg.vocab_size < 2 {
            c.fail("contract/field", "model.vocab_size", "must be >= 2");
        }
        if let Some(n) = &name {
            if &cfg.name != n {
                c.fail(
                    "contract/field",
                    "model.name",
                    format!("{:?} disagrees with top-level name {n:?}", cfg.name),
                );
            }
        }
        if let Some(b) = batch_size {
            if cfg.batch_size as u64 != b {
                c.fail(
                    "contract/field",
                    "model.batch_size",
                    format!("{} disagrees with top-level batch_size {b}", cfg.batch_size),
                );
            }
        }
        if let Some(l) = seq_len {
            if cfg.seq_len as u64 != l {
                c.fail(
                    "contract/field",
                    "model.seq_len",
                    format!("{} disagrees with top-level seq_len {l}", cfg.seq_len),
                );
            }
        }
        if let Some(lens) = &eval_lens {
            let cfg_lens: Vec<u64> = cfg.eval_lens.iter().map(|&x| x as u64).collect();
            if &cfg_lens != lens {
                c.fail(
                    "contract/field",
                    "model.eval_lens",
                    format!("{cfg_lens:?} disagrees with top-level eval_lens {lens:?}"),
                );
            }
        }
    }

    check_decode(c, j, cfg.as_ref(), eval_lens.as_deref());
}

fn check_decode(c: &mut Checker, j: &Json, cfg: Option<&ModelCfg>, eval_lens: Option<&[u64]>) {
    let obj = match j.as_obj() {
        Ok(o) => o,
        Err(_) => return,
    };
    // Both keys must exist (null is fine); exactly one may be non-null.
    let decode = obj.get("decode");
    let reason = obj.get("decode_unsupported");
    if decode.is_none() || reason.is_none() {
        c.fail(
            "contract/decode",
            "decode",
            "decode support status missing (`decode` and `decode_unsupported` \
             must both be present, one of them null) — re-run `make artifacts`",
        );
        return;
    }
    let decode = match decode {
        Some(Json::Null) => None,
        d => d,
    };
    let reason = match reason {
        Some(Json::Null) => None,
        r => r,
    };
    match (decode, reason) {
        (Some(_), Some(_)) => {
            c.fail(
                "contract/decode",
                "decode_unsupported",
                "both a decode state spec and an unsupported reason are set — \
                 they are mutually exclusive",
            );
            return;
        }
        (None, Some(r)) => {
            match r.as_str() {
                Ok(s) if !s.is_empty() => {}
                _ => c.fail(
                    "contract/decode",
                    "decode_unsupported",
                    "must be a non-empty reason string when decode is null",
                ),
            }
            // The emitter decodes every preset layout — window <= 0
            // attention carries a capped kv_cap cache instead of a rolling
            // window — so a non-null reason on a parseable config always
            // contradicts it (stale pre-kv_cap manifest: re-run
            // `make artifacts`).
            if let Some(cfg) = cfg {
                c.fail(
                    "contract/decode",
                    "decode_unsupported",
                    format!(
                        "set for arch {:?} window {} — the emitter decodes \
                         every preset layout (window <= 0 attention uses the \
                         capped kv_cap cache), so this manifest disagrees \
                         with the emitter",
                        cfg.arch, cfg.window
                    ),
                );
            }
            return;
        }
        (None, None) => {
            c.fail(
                "contract/decode",
                "decode",
                "decode and decode_unsupported are both null — the support \
                 status is unknowable",
            );
            return;
        }
        (Some(_), None) => {}
    }
    let d = decode.expect("checked above");
    let batch = uint_field(c, d, "decode", "batch", 1);
    if let Some(lens) = field(c, d, "decode", "prefill_lens")
        .and_then(|v| uint_list(c, v, "decode.prefill_lens", 1))
    {
        if lens.is_empty() {
            c.fail("contract/decode", "decode.prefill_lens", "must be non-empty");
        } else if !lens.windows(2).all(|p| p[0] < p[1]) {
            c.fail(
                "contract/decode",
                "decode.prefill_lens",
                format!("must be strictly increasing (sorted, no repeats), got {lens:?}"),
            );
        } else if let Some(el) = eval_lens {
            if lens != el {
                c.fail(
                    "contract/decode",
                    "decode.prefill_lens",
                    format!(
                        "{lens:?} != eval_lens {el:?} — the emitter lowers one \
                         prefill artifact per eval length"
                    ),
                );
            }
        }
    }

    let state = match field(c, d, "decode", "state")
        .and_then(|v| leaf_list(c, v, "decode.state", "contract/decode"))
    {
        Some(s) => s,
        None => return,
    };

    // Leaf 0 is always the scalar i32 `pos`; nothing else may claim it.
    match state.first() {
        Some(l) if l.name == "pos" && l.shape.is_empty() && l.dtype == "int32" => {}
        Some(l) => c.fail(
            "contract/decode",
            "decode.state[0]",
            format!("leaf 0 must be pos [] int32, got {}", l.describe()),
        ),
        None => c.fail("contract/decode", "decode.state", "must list at least the pos leaf"),
    }
    for (i, l) in state.iter().enumerate().skip(1) {
        if l.name == "pos" {
            c.fail(
                "contract/decode",
                &format!("decode.state[{i}]"),
                "second `pos` leaf — the scalar position is leaf 0, once",
            );
        }
        if let (Some(b), Some(&dim0)) = (batch, l.shape.first()) {
            if dim0 != b {
                c.fail(
                    "contract/decode",
                    &format!("decode.state[{i}].shape"),
                    format!("dim 0 is {dim0} but decode.batch is {b}"),
                );
            }
        }
    }

    // KV caches appear iff the layout has SWA blocks (this is what flips
    // `DecodeSpec::position_dependent` and forces gang admission in serve).
    let has_kv = state
        .iter()
        .any(|l| l.name.ends_with(".k_cache") || l.name.ends_with(".v_cache"));
    if let Some(cfg) = cfg {
        let layout = cfg.block_layout().unwrap_or_default();
        let has_swa = layout.contains(&"swa");
        if has_kv && !has_swa {
            c.fail(
                "contract/decode",
                "decode.state",
                format!(
                    "KV-cache leaves present but the {:?} layout has no swa \
                     blocks — position_dependent would gang-admit for nothing",
                    cfg.arch
                ),
            );
        }
        if has_swa && !has_kv {
            c.fail(
                "contract/decode",
                "decode.state",
                format!(
                    "{:?} layout has swa blocks but no KV-cache leaves — \
                     position_dependent would miss the gang-admission requirement",
                    cfg.arch
                ),
            );
        }

        // kv_cap: a full-attention layout (swa with window 0) must declare
        // the cache capacity the coordinator stops requests at; everything
        // else must leave it null/absent. The declared value must match
        // both the config derivation and the cache leaves themselves —
        // a lie in either direction means silent slot-(cap-1) overwrites
        // (XLA clamps the scatter index) or spuriously refused requests.
        let full_attn = has_swa && cfg.window == 0;
        let kv_cap = match d.as_obj().ok().and_then(|o| o.get("kv_cap")) {
            Some(Json::Null) | None => None,
            Some(v) => Some(v),
        };
        match (full_attn, kv_cap) {
            (true, None) => c.fail(
                "contract/decode",
                "decode.kv_cap",
                format!(
                    "missing for full-attention layout {:?} (swa with window 0) \
                     — the coordinator cannot bound the KV cache without it",
                    cfg.arch
                ),
            ),
            (true, Some(v)) => match as_uint(v) {
                None => c.fail(
                    "contract/decode",
                    "decode.kv_cap",
                    format!(
                        "must be an integer-valued number >= 1 ({} found; \
                         Json::as_usize would silently truncate)",
                        v.kind()
                    ),
                ),
                Some(0) => c.fail("contract/decode", "decode.kv_cap", "must be >= 1"),
                Some(cap) => {
                    if cap != cfg.kv_cap() as u64 {
                        c.fail(
                            "contract/decode",
                            "decode.kv_cap",
                            format!(
                                "declares {cap} but ModelCfg::kv_cap derives {} \
                                 (2x the longest of seq_len and eval_lens)",
                                cfg.kv_cap()
                            ),
                        );
                    }
                    for (i, l) in state.iter().enumerate() {
                        let is_cache = l.name.ends_with(".k_cache")
                            || l.name.ends_with(".v_cache");
                        if is_cache && l.shape.get(1) != Some(&cap) {
                            c.fail(
                                "contract/decode",
                                &format!("decode.state[{i}].shape"),
                                format!(
                                    "cache `{}` has capacity dim {:?} but \
                                     decode.kv_cap declares {cap}",
                                    l.name,
                                    l.shape.get(1)
                                ),
                            );
                        }
                    }
                }
            },
            (false, Some(_)) => c.fail(
                "contract/decode",
                "decode.kv_cap",
                format!(
                    "set for arch {:?} window {} — only full-attention layouts \
                     (swa with window 0) carry a capped KV lane; rolling-window \
                     and pure-SSM layouts must leave it null",
                    cfg.arch, cfg.window
                ),
            ),
            (false, None) => {}
        }

        // The full mirror: the emitted flat state must equal state_spec.
        if let Some(b) = batch {
            match expected_state(cfg, b) {
                Ok(expected) => {
                    if expected.len() != state.len() {
                        c.fail(
                            "contract/state-mirror",
                            "decode.state",
                            format!(
                                "{} leaves emitted but state_spec({}, batch {b}) \
                                 yields {}",
                                state.len(),
                                cfg.name,
                                expected.len()
                            ),
                        );
                    }
                    for (i, (got, want)) in state.iter().zip(&expected).enumerate() {
                        if got != want {
                            c.fail(
                                "contract/state-mirror",
                                &format!("decode.state[{i}]"),
                                format!(
                                    "leaf {i} is `{}` but state_spec says `{}`",
                                    got.describe(),
                                    want.describe()
                                ),
                            );
                        }
                    }
                }
                Err(e) => c.fail(
                    "contract/state-mirror",
                    "decode.state",
                    format!("cannot derive state_spec from the model section: {e}"),
                ),
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Entry points
// ---------------------------------------------------------------------------

/// Check one manifest given its raw bytes; `file` labels the findings.
pub fn check_manifest_bytes(file: &str, bytes: &[u8]) -> Vec<Finding> {
    let mut c = Checker { file, lines: BTreeMap::new(), out: Vec::new() };
    let j = match Json::parse_bytes(bytes) {
        Ok(j) => j,
        Err(e) => {
            let line = match &e {
                JsonError::Parse(off, _) => {
                    1 + bytes[..(*off).min(bytes.len())]
                        .iter()
                        .filter(|&&b| b == b'\n')
                        .count()
                }
                _ => 1,
            };
            c.out.push(Finding::new(
                file,
                line,
                "contract/parse",
                format!("manifest does not parse: {e}"),
            ));
            return c.out;
        }
    };
    // Parse succeeded, so the bytes are valid UTF-8.
    c.lines = key_lines(std::str::from_utf8(bytes).unwrap_or(""));
    check_root(&mut c, &j);
    c.out
}

/// Check one manifest file on disk.
pub fn check_manifest_file(path: &Path) -> Vec<Finding> {
    let label = path.display().to_string();
    match std::fs::read(path) {
        Ok(bytes) => check_manifest_bytes(&label, &bytes),
        Err(e) => vec![Finding::new(label, 1, "contract/parse", format!("cannot read: {e}"))],
    }
}

/// The committed golden manifest fixtures (`rust/tests/golden/*.manifest.json`
/// under the repo root) — real emitter output pinned in-tree so the contract
/// pass always has input, even where no artifacts/ exists.
pub fn golden_manifests(root: &Path) -> Vec<PathBuf> {
    let dir = root.join("rust").join("tests").join("golden");
    let mut out: Vec<PathBuf> = std::fs::read_dir(&dir)
        .into_iter()
        .flatten()
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.file_name().is_some_and(|n| {
            n.to_string_lossy().ends_with(".manifest.json")
        }))
        .collect();
    out.sort();
    out
}

/// Freshly emitted manifests under an artifacts root (absent dir => empty).
pub fn artifact_manifests(artifacts_root: &Path) -> Vec<PathBuf> {
    let mut out: Vec<PathBuf> = std::fs::read_dir(artifacts_root)
        .into_iter()
        .flatten()
        .filter_map(|e| e.ok())
        .map(|e| e.path().join("manifest.json"))
        .filter(|p| p.exists())
        .collect();
    out.sort();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A minimal fully-valid manifest: mamba, 1 layer, d_model 4, expand 2
    /// (d_inner 8), conv_kernel 2, d_state 2, decode batch 1. Params sum:
    /// embed 16*4 + w 4*4 = 80.
    fn valid() -> String {
        r#"{
 "analysis": {"active_params": 80, "fwd_flops_per_token": 96.0, "total_params": 80},
 "batch_size": 2,
 "decode": {
  "batch": 1,
  "prefill_lens": [8],
  "state": [
   {"dtype": "int32", "name": "pos", "shape": []},
   {"dtype": "float32", "name": "blocks.0.conv", "shape": [1, 1, 8]},
   {"dtype": "float32", "name": "blocks.0.ssm", "shape": [1, 8, 2]}
  ]
 },
 "decode_unsupported": null,
 "eval_lens": [8],
 "micro_batch": 1,
 "model": {
  "arch": "mamba", "attn_moe": "none", "attn_moe_experts": 8,
  "batch_size": 2, "conv_kernel": 2, "d_model": 4, "d_state": 2,
  "decode_batch": 1, "dt_rank": 1, "eval_lens": [8], "expand": 2,
  "ffn_moe": {"balance_loss": 0.0, "jitter": 0.0, "num_experts": 1, "top_k": 1},
  "ffn_moe_share_router": false, "micro_batch": 0, "mlp_mult": 2,
  "n_heads": 2, "n_layers": 1, "name": "t",
  "rom": {"balance_loss": 0.0, "jitter": 0.0, "num_experts": 8, "top_k": 1},
  "rom_targets": ["conv"], "routing": "shared", "seq_len": 8,
  "vocab_size": 16, "window": 4
 },
 "name": "t",
 "num_experts": 8,
 "num_param_leaves": 2,
 "num_routers": 1,
 "params": [
  {"dtype": "float32", "name": "embed", "shape": [16, 4]},
  {"dtype": "float32", "name": "w", "shape": [4, 4]}
 ],
 "seq_len": 8
}"#
        .to_string()
    }

    fn check(text: &str) -> Vec<Finding> {
        check_manifest_bytes("m.json", text.as_bytes())
    }

    #[test]
    fn valid_manifest_is_clean() {
        let f = check(&valid());
        assert!(f.is_empty(), "unexpected findings: {f:?}");
    }

    #[test]
    fn key_lines_index_points_into_the_file() {
        let text = valid();
        let lines = key_lines(&text);
        // "decode" opens on line 4; state leaf 1's shape sits on line 9.
        assert_eq!(lines["decode"], 4);
        assert_eq!(lines["decode.state[1]"], 9);
        assert_eq!(line_of(&lines, "decode.state[1].shape"), 9);
        // Missing keys anchor at the nearest ancestor.
        assert_eq!(line_of(&lines, "decode.nope"), 4);
    }

    #[test]
    fn mutated_state_shape_is_flagged_with_line() {
        let bad = valid().replace("\"shape\": [1, 8, 2]", "\"shape\": [1, 8, 3]");
        let f = check(&bad);
        assert!(
            f.iter().any(|f| f.rule == "contract/state-mirror"
                && f.message.contains("decode.state[2]")
                && f.line == 10),
            "{f:?}"
        );
    }

    #[test]
    fn missing_required_field_is_flagged() {
        let bad = valid().replace(" \"batch_size\": 2,\n", "");
        let f = check(&bad);
        assert!(
            f.iter().any(|f| f.rule == "contract/field" && f.message.contains("batch_size")),
            "{f:?}"
        );
    }

    #[test]
    fn fractional_count_is_flagged_not_truncated() {
        let bad = valid().replace("\"batch_size\": 2,\n \"decode\"", "\"batch_size\": 2.5,\n \"decode\"");
        let f = check(&bad);
        assert!(
            f.iter().any(|f| f.message.contains("integer-valued")),
            "{f:?}"
        );
    }

    #[test]
    fn param_sum_mismatch_is_flagged() {
        let bad = valid().replace("\"total_params\": 80", "\"total_params\": 81");
        let f = check(&bad);
        assert!(
            f.iter().any(|f| f.rule == "contract/analysis"
                && f.message.contains("sum to 80")),
            "{f:?}"
        );
    }

    #[test]
    fn decode_xor_unsupported_is_enforced() {
        // Null out decode while leaving decode_unsupported null: unknowable.
        let start = valid().find("\"decode\": {").unwrap();
        let end = valid().find("\n \"decode_unsupported\"").unwrap();
        let mut bad = valid();
        bad.replace_range(start..end, "\"decode\": null,");
        let f = check(&bad);
        assert!(
            f.iter().any(|f| f.rule == "contract/decode" && f.message.contains("both null")),
            "{f:?}"
        );
    }

    #[test]
    fn unjustified_unsupported_reason_is_flagged() {
        // Every preset layout decodes now (full attention included), so any
        // claimed unsupported reason contradicts the emitter.
        let start = valid().find("\"decode\": {").unwrap();
        let end = valid().find("\n \"decode_unsupported\"").unwrap();
        let mut bad = valid();
        bad.replace_range(start..end, "\"decode\": null,");
        let bad = bad.replace(
            "\"decode_unsupported\": null",
            "\"decode_unsupported\": \"because\"",
        );
        let f = check(&bad);
        assert!(
            f.iter().any(|f| f.rule == "contract/decode"
                && f.message.contains("decodes every preset layout")),
            "{f:?}"
        );
    }

    #[test]
    fn swa_mirror_expects_kv_leaves() {
        let cfg = ModelCfg::parse(
            &Json::parse(&valid()).unwrap().get("model").unwrap().clone(),
        )
        .unwrap();
        let mut swa_cfg = cfg.clone();
        swa_cfg.arch = "samba".into();
        let spec = expected_state(&swa_cfg, 2).unwrap();
        let names: Vec<&str> = spec.iter().map(|l| l.name.as_str()).collect();
        assert_eq!(
            names,
            vec!["pos", "blocks.0.conv", "blocks.0.ssm", "blocks.1.k_cache", "blocks.1.v_cache"]
        );
        assert_eq!(spec[3].shape, vec![2, 4, 4]); // [B, window, d_model]
        // window 0 = full attention: capacity flips to the kv_cap derivation.
        swa_cfg.window = 0;
        let spec = expected_state(&swa_cfg, 2).unwrap();
        assert_eq!(spec[3].shape, vec![2, swa_cfg.kv_cap() as u64, 4]);
        assert_eq!(spec[4].shape, vec![2, 16, 4]); // 2 * max(seq_len 8, [8])
    }

    /// Full-attention variant of `valid()`: llama layout (1 group = swa+mlp),
    /// window 0, seq_len 8, eval_lens [8] -> kv_cap 16.
    fn valid_full_attn() -> String {
        valid()
            .replace("\"arch\": \"mamba\"", "\"arch\": \"llama\"")
            .replace("\"window\": 4", "\"window\": 0")
            .replace(
                r#""prefill_lens": [8],
  "state": [
   {"dtype": "int32", "name": "pos", "shape": []},
   {"dtype": "float32", "name": "blocks.0.conv", "shape": [1, 1, 8]},
   {"dtype": "float32", "name": "blocks.0.ssm", "shape": [1, 8, 2]}
  ]"#,
                r#""kv_cap": 16,
  "prefill_lens": [8],
  "state": [
   {"dtype": "int32", "name": "pos", "shape": []},
   {"dtype": "float32", "name": "blocks.0.k_cache", "shape": [1, 16, 4]},
   {"dtype": "float32", "name": "blocks.0.v_cache", "shape": [1, 16, 4]}
  ]"#,
            )
    }

    #[test]
    fn full_attention_manifest_is_clean() {
        let f = check(&valid_full_attn());
        assert!(f.is_empty(), "unexpected findings: {f:?}");
    }

    #[test]
    fn missing_kv_cap_on_full_attention_is_flagged() {
        let bad = valid_full_attn().replace("\"kv_cap\": 16,\n  ", "");
        let f = check(&bad);
        assert!(
            f.iter().any(|f| f.rule == "contract/decode"
                && f.message.contains("decode.kv_cap")
                && f.message.contains("missing for full-attention")),
            "{f:?}"
        );
    }

    #[test]
    fn fractional_kv_cap_is_flagged_not_truncated() {
        let bad = valid_full_attn().replace("\"kv_cap\": 16,", "\"kv_cap\": 16.5,");
        let f = check(&bad);
        assert!(
            f.iter().any(|f| f.rule == "contract/decode"
                && f.message.contains("decode.kv_cap")
                && f.message.contains("integer-valued")),
            "{f:?}"
        );
    }

    #[test]
    fn kv_cap_disagreeing_with_derivation_and_caches_is_flagged() {
        // 12 != the kv_cap derivation (16) and != the cache leaves' dim 1.
        let bad = valid_full_attn().replace("\"kv_cap\": 16,", "\"kv_cap\": 12,");
        let f = check(&bad);
        assert!(
            f.iter().any(|f| f.rule == "contract/decode"
                && f.message.contains("ModelCfg::kv_cap derives 16")),
            "{f:?}"
        );
        assert!(
            f.iter().any(|f| f.rule == "contract/decode"
                && f.message.contains("capacity dim")
                && f.message.contains("decode.state[1]")),
            "{f:?}"
        );
    }

    #[test]
    fn kv_cap_on_non_full_attention_layout_is_flagged() {
        // The mamba fixture has no full-attn lane; declaring a cap lies to
        // the coordinator about a cache that does not exist.
        let bad = valid().replace(
            "\"prefill_lens\": [8],",
            "\"kv_cap\": 16,\n  \"prefill_lens\": [8],",
        );
        let f = check(&bad);
        assert!(
            f.iter().any(|f| f.rule == "contract/decode"
                && f.message.contains("only full-attention layouts")),
            "{f:?}"
        );
    }

    #[test]
    fn unparseable_bytes_report_parse_rule() {
        let f = check_manifest_bytes("m.json", b"{\"a\": \xFF}");
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, "contract/parse");
    }
}
