//! Analytic params/FLOPS accounting — the rust mirror of
//! python/compile/analysis.py (same formulas; the cross-check against the
//! manifest values emitted by python is an integration test).

pub mod flops;
