//! Offline static analysis: the `rom analyze` subsystem plus the analytic
//! params/FLOPS accounting mirror.
//!
//! Three passes, none of which touch PJRT or a device:
//!
//! * [`contract`] — machine-checks the python→rust `manifest.json` calling
//!   convention (field/type universe, flat param/state leaf consistency,
//!   decode invariants, a full rust-side mirror of
//!   `python/compile/decode.py::state_spec`).
//! * [`schema`] — diffs the `BENCH_runtime.json` field universe emitted by
//!   `benches/bench_*.rs` against the schema tables in EXPERIMENTS.md, both
//!   directions, so doc drift fails CI.
//! * [`lint`] — a source scanner for project invariants the compiler cannot
//!   see (bench-write confinement, thread-spawn confinement, no `.unwrap()`
//!   in `coordinator/` non-test code, `// SAFETY:` before every `unsafe`).
//!
//! [`flops`] is the analytic accounting mirror of
//! python/compile/analysis.py (pre-dates `rom analyze`; the manifest
//! cross-check against its formulas is an integration test).

pub mod contract;
pub mod flops;
pub mod lint;
pub mod schema;

use std::fmt;
use std::path::PathBuf;

/// One analyzer finding, anchored to a file and 1-based line so editors and
/// CI logs can jump straight to it.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub file: String,
    pub line: usize,
    /// Stable rule identifier, e.g. `contract/state-mirror` or
    /// `lint/thread-spawn`.
    pub rule: &'static str,
    pub message: String,
}

impl Finding {
    pub fn new(
        file: impl Into<String>,
        line: usize,
        rule: &'static str,
        message: impl Into<String>,
    ) -> Finding {
        Finding { file: file.into(), line: line.max(1), rule, message: message.into() }
    }
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.message)
    }
}

/// Repo root for tree-wide passes: `ROM_REPO_ROOT` when set, else probe the
/// compile-time manifest dir and its parent for the directory that holds
/// EXPERIMENTS.md (the workspace manifest may sit at the repo root or in
/// `rust/`).
pub fn repo_root() -> PathBuf {
    if let Ok(p) = std::env::var("ROM_REPO_ROOT") {
        return PathBuf::from(p);
    }
    let manifest_dir = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    for cand in [manifest_dir.clone(), manifest_dir.join("..")] {
        if cand.join("EXPERIMENTS.md").exists() {
            return cand;
        }
    }
    manifest_dir
}
