//! Forward FLOPS/token for a model config (Table 1's FLOPS column).
//!
//! Mirrors python/compile/analysis.py::flops_per_token exactly — the
//! integration test cross-checks this against every manifest's recorded
//! value, keeping the two implementations in lockstep.

use anyhow::Result;

use crate::config::ModelCfg;

fn mamba2_in_width(cfg: &ModelCfg) -> usize {
    let di = cfg.d_inner();
    2 * di + 2 * cfg.d_state + cfg.n_heads // z, x, B, C, dt
}

fn gdn_in_width(cfg: &ModelCfg) -> usize {
    let di = cfg.d_inner();
    3 * di + di + 2 * cfg.n_heads // q, k, v, gate, alpha, beta
}

pub fn flops_per_token(cfg: &ModelCfg, seq_len: usize) -> Result<f64> {
    let d = cfg.d_model as f64;
    let di = cfg.d_inner() as f64;
    let n = cfg.d_state as f64;
    let r = cfg.dt_rank as f64;
    let k = if cfg.rom.enabled() { cfg.rom.top_k as f64 } else { 1.0 };
    let mut fl = 0.0;
    for kind in cfg.block_layout()? {
        match kind {
            "mamba" => {
                fl += 2.0 * k * (d * di) * 2.0; // conv + gate banks
                fl += 2.0 * k * (di * d); // out bank
                fl += 2.0 * (di * (r + 2.0 * n) + r * di); // x/dt projections
                fl += 2.0 * cfg.conv_kernel as f64 * di; // depthwise conv
                fl += 10.0 * di * n; // discretize + scan + readout
                if cfg.rom.enabled() && !cfg.rom_targets.is_empty() {
                    let nr = if cfg.routing == "shared" {
                        1.0
                    } else {
                        cfg.rom_targets.len() as f64
                    };
                    fl += 2.0 * nr * d * cfg.rom.num_experts as f64;
                }
            }
            "mamba2" => {
                fl += 2.0 * k * d * mamba2_in_width(cfg) as f64 + 2.0 * k * di * d;
                fl += 2.0 * cfg.conv_kernel as f64 * di + 10.0 * di * n;
                if cfg.rom.enabled() {
                    fl += 2.0 * d * cfg.rom.num_experts as f64;
                }
            }
            "gdn" => {
                fl += 2.0 * k * d * gdn_in_width(cfg) as f64 + 2.0 * k * di * d;
                fl += 2.0 * cfg.conv_kernel as f64 * di;
                fl += 8.0 * di * (di / cfg.n_heads as f64); // delta rule
                if cfg.rom.enabled() {
                    fl += 2.0 * d * cfg.rom.num_experts as f64;
                }
            }
            "swa" => {
                fl += 2.0 * 4.0 * d * d; // q,k,v,o
                let t_eff = if cfg.window > 0 {
                    seq_len.min(cfg.window) as f64
                } else {
                    seq_len as f64
                };
                fl += 2.0 * 2.0 * d * t_eff;
                if cfg.attn_moe != "none" {
                    fl += 2.0 * d * cfg.attn_moe_experts as f64;
                }
            }
            "mlp" => {
                let ke = if cfg.ffn_moe.enabled() { cfg.ffn_moe.top_k as f64 } else { 1.0 };
                fl += 2.0 * ke * 3.0 * d * (cfg.mlp_mult as f64 * d);
                if cfg.ffn_moe.enabled() && !cfg.ffn_moe_share_router {
                    fl += 2.0 * d * cfg.ffn_moe.num_experts as f64;
                }
            }
            other => anyhow::bail!("unknown block kind {other}"),
        }
    }
    fl += 2.0 * d * cfg.vocab_size as f64; // lm head
    Ok(fl)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::json::Json;

    fn cfg(arch: &str, rom_experts: usize) -> ModelCfg {
        let rom_targets = if rom_experts > 1 {
            r#"["conv", "gate", "out"]"#
        } else {
            "[]"
        };
        let doc = format!(
            r#"{{
          "name": "t", "arch": "{arch}", "vocab_size": 512, "d_model": 96,
          "n_layers": 2, "expand": 2, "d_state": 16, "dt_rank": 6,
          "conv_kernel": 4, "n_heads": 4, "window": 64, "mlp_mult": 2,
          "rom_targets": {rom_targets}, "routing": "shared",
          "rom": {{"num_experts": {rom_experts}, "top_k": 1, "jitter": 0.0, "balance_loss": 0.0}},
          "ffn_moe": {{"num_experts": 1, "top_k": 1, "jitter": 0.0, "balance_loss": 0.0}},
          "ffn_moe_share_router": false, "attn_moe": "none", "attn_moe_experts": 8,
          "batch_size": 8, "seq_len": 128, "eval_lens": [128]
        }}"#
        );
        ModelCfg::parse(&Json::parse(&doc).unwrap()).unwrap()
    }

    #[test]
    fn rom_top1_adds_only_router_flops() {
        let dense = flops_per_token(&cfg("mamba", 1), 128).unwrap();
        let rom = flops_per_token(&cfg("mamba", 8), 128).unwrap();
        assert!(rom > dense);
        assert!(rom < dense * 1.05, "rom {rom} dense {dense}");
    }

    #[test]
    fn samba_has_attention_and_mlp_flops() {
        let mamba = flops_per_token(&cfg("mamba", 1), 128).unwrap();
        let samba = flops_per_token(&cfg("samba", 1), 128).unwrap();
        assert!(samba > mamba);
    }

    #[test]
    fn window_caps_attention_cost() {
        let mut c = cfg("llama", 1);
        c.window = 0; // full attention
        let full = flops_per_token(&c, 1024).unwrap();
        c.window = 64;
        let swa = flops_per_token(&c, 1024).unwrap();
        assert!(swa < full);
    }
}
