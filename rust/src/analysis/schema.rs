//! BENCH_runtime.json schema drift checker.
//!
//! EXPERIMENTS.md carries a "§BENCH_runtime.json schema" section with one
//! table per emitting bench. The benches emit fields as
//! `("name", Json::num(..))` tuples (plus `format!("gen_prefill_L{l}_ms")`
//! for the per-length pattern). This pass parses both sides and diffs them
//! **in both directions**, per bench:
//!
//! * a field emitted by a bench but absent from its table → the docs are
//!   stale ([`RULE_UNDOCUMENTED`], anchored at the emission site);
//! * a field documented but no longer emitted → the docs promise data the
//!   trajectory record will never carry ([`RULE_STALE`], anchored at the
//!   doc row).
//!
//! Pattern fields use `{}`-normalised matching: the doc row
//! `gen_prefill_L{L}_ms` and the emission `format!("gen_prefill_L{l}_ms")`
//! both normalise to `gen_prefill_L{}_ms`. A committed BENCH_runtime.json,
//! when present, is checked as a third witness: every key must match a
//! documented field or pattern.

use std::path::Path;

use crate::analysis::Finding;
use crate::substrate::json::Json;

pub const RULE_DOC: &str = "schema/doc";
pub const RULE_UNDOCUMENTED: &str = "schema/undocumented";
pub const RULE_STALE: &str = "schema/stale";
pub const RULE_RECORD: &str = "schema/record";

const SECTION: &str = "BENCH_runtime.json schema";

/// Collapse every `{...}` placeholder to `{}` so doc-side `{L}` and
/// rust-side `{l}` compare equal.
fn normalize(field: &str) -> String {
    let mut out = String::new();
    let mut it = field.chars();
    while let Some(c) = it.next() {
        if c == '{' {
            for c2 in it.by_ref() {
                if c2 == '}' {
                    break;
                }
            }
            out.push_str("{}");
        } else {
            out.push(c);
        }
    }
    out
}

/// Does a concrete record key match a (normalised) field pattern?
/// `gen_prefill_L256_ms` matches `gen_prefill_L{}_ms`; patterns without
/// `{}` require equality.
fn matches_pattern(key: &str, pattern: &str) -> bool {
    if !pattern.contains("{}") {
        return key == pattern;
    }
    let parts: Vec<&str> = pattern.split("{}").collect();
    let mut rest = match key.strip_prefix(parts[0]) {
        Some(r) => r,
        None => return false,
    };
    for (i, part) in parts.iter().enumerate().skip(1) {
        if i == parts.len() - 1 {
            // Final segment must terminate the key, with a non-empty fill.
            return !rest.is_empty() && rest.len() > part.len() && rest.ends_with(part);
        }
        match rest.find(part) {
            Some(pos) if pos > 0 || part.is_empty() => rest = &rest[pos + part.len()..],
            _ => return false,
        }
    }
    true
}

/// One documented field row.
struct DocField {
    raw: String,
    norm: String,
    line: usize,
    bench: String,
}

/// Parse the schema section out of EXPERIMENTS.md text. Returns the rows
/// plus any structural findings (missing section, rows outside a bench
/// table, duplicates).
fn doc_fields(doc: &str, doc_name: &str) -> (Vec<DocField>, Vec<Finding>) {
    let mut out = Vec::new();
    let mut findings = Vec::new();
    let mut in_section = false;
    let mut section_seen = false;
    let mut bench: Option<String> = None;
    for (i, line) in doc.lines().enumerate() {
        let ln = i + 1;
        if line.starts_with("## ") {
            in_section = line.contains(SECTION);
            section_seen |= in_section;
            bench = None;
            continue;
        }
        if !in_section {
            continue;
        }
        if line.contains("--bench ") {
            // "Emitted by `cargo bench --bench bench_runtime`:" introduces
            // the table that follows.
            if let Some(rest) = line.split("--bench ").nth(1) {
                let name: String = rest
                    .chars()
                    .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
                    .collect();
                bench = Some(name);
            }
            continue;
        }
        if !line.trim_start().starts_with('|') {
            continue;
        }
        // First backticked token is the field name; header and separator
        // rows have none.
        let Some(start) = line.find('`') else { continue };
        let Some(len) = line[start + 1..].find('`') else { continue };
        let raw = line[start + 1..start + 1 + len].to_string();
        let Some(bench) = bench.clone() else {
            findings.push(Finding::new(
                doc_name,
                ln,
                RULE_DOC,
                format!(
                    "schema row `{raw}` appears before any \"Emitted by \
                     `cargo bench --bench ...`\" table introduction"
                ),
            ));
            continue;
        };
        let norm = normalize(&raw);
        if out.iter().any(|f: &DocField| f.norm == norm && f.bench == bench) {
            findings.push(Finding::new(
                doc_name,
                ln,
                RULE_DOC,
                format!("duplicate schema row `{raw}` in the {bench} table"),
            ));
            continue;
        }
        out.push(DocField { raw, norm, line: ln, bench });
    }
    if !section_seen {
        findings.push(Finding::new(
            doc_name,
            1,
            RULE_DOC,
            format!("no `## §{SECTION}` section found — the bench field universe is undocumented"),
        ));
    }
    (out, findings)
}

/// One field emission site in a bench source.
struct Emitted {
    raw: String,
    norm: String,
    line: usize,
}

/// Scan one bench source for `("field", Json::...)` emission sites. The
/// three idioms in tree:
///
/// ```text
/// ("variant", Json::str(..))                       // &str key
/// ("gen_variant".into(), Json::str(..))            // String key
/// (format!("gen_prefill_L{l}_ms"), Json::num(..))  // pattern key
/// ```
fn emitted_fields(src: &str) -> Vec<Emitted> {
    let mut out = Vec::new();
    for (i, line) in src.lines().enumerate() {
        let b = line.as_bytes();
        let mut j = 0;
        while j < b.len() {
            if b[j] != b'"' {
                j += 1;
                continue;
            }
            let start = j + 1;
            let mut end = start;
            while end < b.len() && b[end] != b'"' {
                if b[end] == b'\\' {
                    end += 1;
                }
                end += 1;
            }
            if end >= b.len() {
                break;
            }
            let lit = &line[start..end];
            j = end + 1;
            let rest = &line[j..];
            let rest = rest.strip_prefix(".into()").unwrap_or(rest);
            let rest = rest.strip_prefix(')').unwrap_or(rest);
            let rest = rest.trim_start();
            let Some(after_comma) = rest.strip_prefix(',') else { continue };
            if after_comma.trim_start().starts_with("Json::") {
                out.push(Emitted {
                    raw: lit.to_string(),
                    norm: normalize(lit),
                    line: i + 1,
                });
            }
        }
    }
    out
}

/// Diff the documented field universe against the emitting bench sources
/// (and, optionally, a committed record's keys).
///
/// `bench_sources` is `[(file_label, source_text)]` — only sources whose
/// stem matches a documented bench table participate; the label's file
/// stem (e.g. `bench_generate` from `rust/benches/bench_generate.rs`) is
/// the join key.
pub fn check_schema(
    doc: &str,
    doc_name: &str,
    bench_sources: &[(String, String)],
    bench_record: Option<(&str, &Json)>,
) -> Vec<Finding> {
    let (docs, mut findings) = doc_fields(doc, doc_name);

    for (label, src) in bench_sources {
        let stem = Path::new(label)
            .file_stem()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_else(|| label.clone());
        let documented: Vec<&DocField> = docs.iter().filter(|d| d.bench == stem).collect();
        let emitted = emitted_fields(src);
        for e in &emitted {
            if !documented.iter().any(|d| d.norm == e.norm) {
                findings.push(Finding::new(
                    label.clone(),
                    e.line,
                    RULE_UNDOCUMENTED,
                    format!(
                        "bench emits `{}` but the {stem} table in {doc_name} \
                         has no such row — document it or stop emitting it",
                        e.raw
                    ),
                ));
            }
        }
        for d in &documented {
            if !emitted.iter().any(|e| e.norm == d.norm) {
                findings.push(Finding::new(
                    doc_name,
                    d.line,
                    RULE_STALE,
                    format!(
                        "documented field `{}` is not emitted anywhere in \
                         {label} — drop the row or restore the emission",
                        d.raw
                    ),
                ));
            }
        }
    }

    // Third witness: a committed record's keys must all be documented.
    if let Some((record_name, record)) = bench_record {
        match record.as_obj() {
            Ok(obj) => {
                for key in obj.keys() {
                    if !docs.iter().any(|d| matches_pattern(key, &d.norm)) {
                        findings.push(Finding::new(
                            record_name,
                            1,
                            RULE_RECORD,
                            format!(
                                "record carries key `{key}` that matches no \
                                 documented field or pattern in {doc_name}"
                            ),
                        ));
                    }
                }
            }
            Err(_) => findings.push(Finding::new(
                record_name,
                1,
                RULE_RECORD,
                format!("record must be a JSON object, got {}", record.kind()),
            )),
        }
    }

    findings
}

/// Tree-wide entry point: EXPERIMENTS.md vs every bench source that calls
/// `merge_bench_json` (local micro-benches that never touch the record are
/// exempt), plus the committed BENCH_runtime.json when present.
pub fn check_tree(root: &Path) -> Vec<Finding> {
    let doc_path = root.join("EXPERIMENTS.md");
    let doc = match std::fs::read_to_string(&doc_path) {
        Ok(d) => d,
        Err(e) => {
            return vec![Finding::new(
                doc_path.display().to_string(),
                1,
                RULE_DOC,
                format!("cannot read: {e}"),
            )]
        }
    };
    let mut sources = Vec::new();
    let bench_dir = root.join("rust").join("benches");
    let mut entries: Vec<_> = std::fs::read_dir(&bench_dir)
        .into_iter()
        .flatten()
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.extension().is_some_and(|x| x == "rs"))
        .collect();
    entries.sort();
    for p in entries {
        if let Ok(src) = std::fs::read_to_string(&p) {
            if src.contains("merge_bench_json(") {
                sources.push((p.display().to_string(), src));
            }
        }
    }
    let record_path = root.join("BENCH_runtime.json");
    let record = std::fs::read_to_string(&record_path)
        .ok()
        .and_then(|t| Json::parse(&t).ok());
    let record_name = record_path.display().to_string();
    check_schema(
        &doc,
        &doc_path.display().to_string(),
        &sources,
        record.as_ref().map(|r| (record_name.as_str(), r)),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = "\
# Experiments

## §BENCH_runtime.json schema

Emitted by `cargo bench --bench bench_runtime`:

| field | units | meaning |
|-------|-------|---------|
| `variant` | — | bundle |
| `fused_step_ms` | ms | step |

Emitted by `cargo bench --bench bench_generate` (merged in):

| field | units | meaning |
|-------|-------|---------|
| `gen_variant` | — | bundle |
| `gen_prefill_L{L}_ms` | ms | per length |

## next section
";

    const RUNTIME_SRC: &str = r#"
    let fields = vec![
        ("variant", Json::str(v)),
        ("fused_step_ms", Json::num(ms)),
    ];
    merge_bench_json(&p, |m| {});
"#;

    const GEN_SRC: &str = r#"
    let mut fields = vec![("gen_variant".into(), Json::str(v))];
    fields.push((format!("gen_prefill_L{l}_ms"), Json::num(ms)));
    merge_bench_json(&p, |m| {});
"#;

    fn sources() -> Vec<(String, String)> {
        vec![
            ("rust/benches/bench_runtime.rs".into(), RUNTIME_SRC.into()),
            ("rust/benches/bench_generate.rs".into(), GEN_SRC.into()),
        ]
    }

    #[test]
    fn in_sync_doc_and_sources_are_clean() {
        let f = check_schema(DOC, "EXPERIMENTS.md", &sources(), None);
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn removed_doc_row_flags_the_emission_site() {
        let doc = DOC.replace("| `fused_step_ms` | ms | step |\n", "");
        let f = check_schema(&doc, "EXPERIMENTS.md", &sources(), None);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, RULE_UNDOCUMENTED);
        assert!(f[0].file.ends_with("bench_runtime.rs"));
        assert_eq!(f[0].line, 4); // the fused_step_ms tuple in RUNTIME_SRC
    }

    #[test]
    fn bogus_doc_row_is_reported_stale_at_its_line() {
        let doc = DOC.replace(
            "| `variant` | — | bundle |",
            "| `variant` | — | bundle |\n| `made_up_field` | ms | nothing emits this |",
        );
        let f = check_schema(&doc, "EXPERIMENTS.md", &sources(), None);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, RULE_STALE);
        assert_eq!(f[0].file, "EXPERIMENTS.md");
        assert!(f[0].message.contains("made_up_field"));
        assert_eq!(f[0].line, 10);
    }

    #[test]
    fn fields_are_matched_per_bench_table() {
        // gen_variant documented under bench_generate but emitted from
        // bench_runtime.rs would be drift in both directions.
        let swapped = vec![("rust/benches/bench_runtime.rs".into(), GEN_SRC.to_string())];
        let f = check_schema(DOC, "EXPERIMENTS.md", &swapped, None);
        assert!(f.iter().any(|f| f.rule == RULE_UNDOCUMENTED), "{f:?}");
        assert!(f.iter().any(|f| f.rule == RULE_STALE), "{f:?}");
    }

    #[test]
    fn record_keys_match_patterns() {
        let record = Json::parse(
            r#"{"variant": "t", "gen_prefill_L256_ms": 1.0, "gen_prefill_L_ms": 1.0, "mystery": 2}"#,
        )
        .unwrap();
        let f = check_schema(
            DOC,
            "EXPERIMENTS.md",
            &sources(),
            Some(("BENCH_runtime.json", &record)),
        );
        // L256 matches the pattern; an empty fill and an unknown key do not.
        let records: Vec<_> = f.iter().filter(|f| f.rule == RULE_RECORD).collect();
        assert_eq!(records.len(), 2, "{f:?}");
        assert!(records.iter().any(|f| f.message.contains("gen_prefill_L_ms")));
        assert!(records.iter().any(|f| f.message.contains("mystery")));
    }

    #[test]
    fn missing_section_is_a_finding() {
        let f = check_schema("# nothing here\n", "EXPERIMENTS.md", &sources(), None);
        assert!(f.iter().any(|f| f.rule == RULE_DOC), "{f:?}");
    }

    #[test]
    fn normalize_and_match() {
        assert_eq!(normalize("gen_prefill_L{L}_ms"), "gen_prefill_L{}_ms");
        assert_eq!(normalize("gen_prefill_L{l}_ms"), "gen_prefill_L{}_ms");
        assert!(matches_pattern("gen_prefill_L512_ms", "gen_prefill_L{}_ms"));
        assert!(!matches_pattern("gen_prefill_L_ms", "gen_prefill_L{}_ms"));
        assert!(!matches_pattern("gen_prefill_L9", "gen_prefill_L{}_ms"));
        assert!(matches_pattern("variant", "variant"));
        assert!(!matches_pattern("variant2", "variant"));
    }
}
