//! Source lint for project invariants the compiler cannot see.
//!
//! Four rules, all scoped to `rust/{src,benches,tests,examples}`:
//!
//! * [`RULE_BENCH_WRITE`] — `BENCH_runtime.json` is only ever written by
//!   `substrate::bench::merge_bench_json` (lock + tmp-rename). A raw
//!   `fs::write`/`File::create`/`OpenOptions` aimed at the record anywhere
//!   else can silently drop concurrent benches' fields.
//! * [`RULE_SPAWN`] — free-running threads live in `substrate::pool`
//!   (and behind its loom-checked `substrate::sync` shim); a stray
//!   `thread::spawn` elsewhere escapes the model-checked surface. Scoped
//!   `std::thread::scope` is allowed anywhere — its joins are structural.
//! * [`RULE_UNWRAP`] — no `.unwrap()` in `coordinator/` non-test code:
//!   the coordinator is the long-running control plane, and a panic there
//!   takes down training/serving with no context. Tests are exempt.
//! * [`RULE_SAFETY`] — every `unsafe` must have a `// SAFETY:` comment on
//!   the same line or within the 8 lines above it (tests included — an
//!   unjustified `unsafe` is no safer for being in a test).
//!
//! Matching happens on *stripped* source — string literals, char literals
//! and comments are blanked first — so a pattern named in a string (this
//! file is full of them) never trips a rule. The escape hatch for a
//! reviewed exception is a `rom-lint: allow(<rule-short-name>)` comment on
//! the same or the preceding line.

use std::path::Path;

use crate::analysis::Finding;

pub const RULE_BENCH_WRITE: &str = "lint/bench-write";
pub const RULE_SPAWN: &str = "lint/thread-spawn";
pub const RULE_UNWRAP: &str = "lint/coordinator-unwrap";
pub const RULE_SAFETY: &str = "lint/safety-comment";

fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Blank out string literals (normal, raw, byte), char literals and
/// comments (line + nested block), preserving newlines and column
/// positions so findings land on real lines.
pub fn strip_code(src: &str) -> String {
    let b = src.as_bytes();
    let mut out = b.to_vec();
    let blank = |out: &mut Vec<u8>, range: std::ops::Range<usize>| {
        for k in range {
            if out[k] != b'\n' {
                out[k] = b' ';
            }
        }
    };
    let mut i = 0;
    while i < b.len() {
        let prev_ident = i > 0 && is_ident(b[i - 1]);
        // Line comment.
        if b[i] == b'/' && b.get(i + 1) == Some(&b'/') {
            let start = i;
            while i < b.len() && b[i] != b'\n' {
                i += 1;
            }
            blank(&mut out, start..i);
            continue;
        }
        // Nested block comment.
        if b[i] == b'/' && b.get(i + 1) == Some(&b'*') {
            let start = i;
            let mut depth = 1;
            i += 2;
            while i < b.len() && depth > 0 {
                if b[i] == b'/' && b.get(i + 1) == Some(&b'*') {
                    depth += 1;
                    i += 2;
                } else if b[i] == b'*' && b.get(i + 1) == Some(&b'/') {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            blank(&mut out, start..i);
            continue;
        }
        // Raw (and raw byte) string: r"..", r#".."#, br#".."# ...
        if !prev_ident && (b[i] == b'r' || (b[i] == b'b' && b.get(i + 1) == Some(&b'r'))) {
            let mut j = i + if b[i] == b'b' { 2 } else { 1 };
            let mut hashes = 0;
            while b.get(j) == Some(&b'#') {
                hashes += 1;
                j += 1;
            }
            if b.get(j) == Some(&b'"') {
                // Scan for the closing quote followed by `hashes` hashes.
                let start = i;
                j += 1;
                loop {
                    match b.get(j) {
                        None => break,
                        Some(&b'"') if b[j + 1..].iter().take(hashes).filter(|&&h| h == b'#').count() == hashes => {
                            j += 1 + hashes;
                            break;
                        }
                        _ => j += 1,
                    }
                }
                blank(&mut out, start..j);
                i = j;
                continue;
            }
        }
        // Byte string b"..".
        if !prev_ident && b[i] == b'b' && b.get(i + 1) == Some(&b'"') {
            let start = i;
            i += 2;
            while i < b.len() && b[i] != b'"' {
                if b[i] == b'\\' {
                    i += 1;
                }
                i += 1;
            }
            i = (i + 1).min(b.len());
            blank(&mut out, start..i);
            continue;
        }
        // Normal string.
        if b[i] == b'"' {
            let start = i;
            i += 1;
            while i < b.len() && b[i] != b'"' {
                if b[i] == b'\\' {
                    i += 1;
                }
                i += 1;
            }
            i = (i + 1).min(b.len());
            blank(&mut out, start..i);
            continue;
        }
        // Char literal vs lifetime.
        if b[i] == b'\'' {
            if b.get(i + 1) == Some(&b'\\') {
                let start = i;
                i += 2; // quote + backslash
                if i < b.len() {
                    i += 1; // the escaped char
                }
                while i < b.len() && b[i] != b'\'' {
                    i += 1;
                }
                i = (i + 1).min(b.len());
                blank(&mut out, start..i);
                continue;
            }
            if b.get(i + 2) == Some(&b'\'') && b.get(i + 1) != Some(&b'\'') {
                blank(&mut out, i..i + 3);
                i += 3;
                continue;
            }
            // Lifetime: leave as-is.
        }
        i += 1;
    }
    // `out` only ever had multi-byte UTF-8 sequences inside literals and
    // comments, which were blanked byte-by-byte to ASCII spaces... except
    // they weren't: blanking replaces each byte with ' ', so any multi-byte
    // char in a literal becomes several spaces — still valid UTF-8. Bytes
    // outside literals are copied verbatim.
    String::from_utf8(out).unwrap_or_default()
}

fn has_word(line: &str, word: &str) -> bool {
    let b = line.as_bytes();
    let mut start = 0;
    while let Some(pos) = line[start..].find(word) {
        let p = start + pos;
        let before_ok = p == 0 || !is_ident(b[p - 1]);
        let after = p + word.len();
        let after_ok = after >= b.len() || !is_ident(b[after]);
        if before_ok && after_ok {
            return true;
        }
        start = p + 1;
    }
    false
}

fn norm_label(label: &str) -> String {
    label.replace('\\', "/")
}

/// Lint a single source file. `label` should be a repo-relative path —
/// rule scoping (coordinator/, substrate/pool.rs, ...) keys off it.
pub fn lint_source(label: &str, src: &str) -> Vec<Finding> {
    let label_n = norm_label(label);
    let stripped = strip_code(src);
    let orig_lines: Vec<&str> = src.lines().collect();
    let stripped_lines: Vec<&str> = stripped.lines().collect();

    // Everything from the first `#[cfg(test)]` / `#[cfg(all(test` to EOF is
    // treated as test code (the tree keeps test mods last in every file).
    let test_start = stripped_lines
        .iter()
        .position(|l| l.contains("#[cfg(test)]") || l.contains("#[cfg(all(test"))
        .unwrap_or(usize::MAX);
    let path_is_test =
        label_n.contains("/tests/") || label_n.contains("/examples/") || label_n.starts_with("tests/");

    let is_pool = label_n.ends_with("substrate/pool.rs");
    let is_bench_home = label_n.ends_with("substrate/bench.rs");
    let in_coordinator = label_n.contains("coordinator/");

    let allowed = |idx: usize, rule: &str| {
        let short = rule.rsplit('/').next().unwrap_or(rule);
        let tag = format!("rom-lint: allow({short})");
        orig_lines[idx].contains(&tag)
            || (idx > 0 && orig_lines[idx - 1].contains(&tag))
    };

    let mut out = Vec::new();
    for (idx, stripped_line) in stripped_lines.iter().enumerate() {
        let orig_line = orig_lines.get(idx).copied().unwrap_or("");
        let ln = idx + 1;
        let in_test = path_is_test || idx >= test_start;

        if !is_bench_home
            && (stripped_line.contains("fs::write")
                || stripped_line.contains("File::create")
                || stripped_line.contains("OpenOptions"))
            && (orig_line.contains("BENCH_runtime") || stripped_line.contains("bench_json_path"))
            && !allowed(idx, RULE_BENCH_WRITE)
        {
            out.push(Finding::new(
                label,
                ln,
                RULE_BENCH_WRITE,
                "writes the bench record directly — all BENCH_runtime.json \
                 writes go through substrate::bench::merge_bench_json \
                 (lock-guarded read-modify-write + atomic rename)",
            ));
        }

        if !in_test
            && !is_pool
            && stripped_line.contains("thread::spawn")
            && !allowed(idx, RULE_SPAWN)
        {
            out.push(Finding::new(
                label,
                ln,
                RULE_SPAWN,
                "free-running thread outside substrate::pool — spawn via the \
                 pool (or a scoped std::thread::scope) so shutdown and the \
                 loom model cover it",
            ));
        }

        if in_coordinator
            && !in_test
            && stripped_line.contains(".unwrap()")
            && !allowed(idx, RULE_UNWRAP)
        {
            out.push(Finding::new(
                label,
                ln,
                RULE_UNWRAP,
                "`.unwrap()` in coordinator non-test code — the control plane \
                 must surface contextful errors, not panic",
            ));
        }

        if has_word(stripped_line, "unsafe") && !allowed(idx, RULE_SAFETY) {
            let lo = idx.saturating_sub(8);
            let justified = (lo..=idx).any(|k| {
                orig_lines.get(k).is_some_and(|l| l.contains("SAFETY:"))
            });
            if !justified {
                out.push(Finding::new(
                    label,
                    ln,
                    RULE_SAFETY,
                    "`unsafe` without a `// SAFETY:` comment on the same line \
                     or within the 8 lines above",
                ));
            }
        }
    }
    out
}

/// Lint a set of `(label, source)` pairs.
pub fn lint_sources(files: &[(String, String)]) -> Vec<Finding> {
    let mut out = Vec::new();
    for (label, src) in files {
        out.extend(lint_source(label, src));
    }
    out
}

/// Lint every `.rs` file under `rust/{src,benches,tests,examples}` of the
/// repo root. Labels are root-relative with forward slashes.
pub fn lint_tree(root: &Path) -> Vec<Finding> {
    fn collect(dir: &Path, out: &mut Vec<std::path::PathBuf>) {
        let mut entries: Vec<_> = std::fs::read_dir(dir)
            .into_iter()
            .flatten()
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .collect();
        entries.sort();
        for p in entries {
            if p.is_dir() {
                collect(&p, out);
            } else if p.extension().is_some_and(|x| x == "rs") {
                out.push(p);
            }
        }
    }
    let mut files = Vec::new();
    for sub in ["src", "benches", "tests", "examples"] {
        collect(&root.join("rust").join(sub), &mut files);
    }
    let mut pairs = Vec::new();
    for p in files {
        if let Ok(src) = std::fs::read_to_string(&p) {
            let label = p
                .strip_prefix(root)
                .map(|r| r.to_string_lossy().replace('\\', "/"))
                .unwrap_or_else(|_| p.display().to_string());
            pairs.push((label, src));
        }
    }
    lint_sources(&pairs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strip_blanks_strings_comments_and_chars() {
        let src = r##"
let a = "thread::spawn"; // thread::spawn
let b = r#"fs::write"#;
let c = '"'; let lt: &'static str = "x";
/* outer /* nested .unwrap() */ still comment */
let d = real_code();
"##;
        let s = strip_code(src);
        assert!(!s.contains("thread::spawn"));
        assert!(!s.contains("fs::write"));
        assert!(!s.contains(".unwrap()"));
        assert!(s.contains("real_code()"));
        assert!(s.contains("&'static str"));
        assert_eq!(s.lines().count(), src.lines().count());
    }

    #[test]
    fn coordinator_unwrap_flagged_outside_tests_only() {
        let src = "fn f() { x.unwrap(); }\n#[cfg(test)]\nmod t { fn g() { y.unwrap(); } }\n";
        let f = lint_source("rust/src/coordinator/fake.rs", src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, RULE_UNWRAP);
        assert_eq!(f[0].line, 1);
        // Same source outside coordinator/ is fine.
        assert!(lint_source("rust/src/runtime/fake.rs", src).is_empty());
    }

    #[test]
    fn spawn_confined_to_pool() {
        let src = "fn f() { std::thread::spawn(|| {}); }\n";
        let f = lint_source("rust/src/data/fake.rs", src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, RULE_SPAWN);
        assert!(lint_source("rust/src/substrate/pool.rs", src).is_empty());
        // thread::scope is structural and allowed anywhere.
        let scoped = "fn f() { std::thread::scope(|s| { s.spawn(|| {}); }); }\n";
        assert!(lint_source("rust/src/data/fake.rs", scoped).is_empty());
    }

    #[test]
    fn unsafe_requires_nearby_safety_comment() {
        let bad = "fn f() { unsafe { g() } }\n";
        let f = lint_source("rust/src/runtime/fake.rs", bad);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, RULE_SAFETY);

        let good = "// SAFETY: g has no preconditions here.\nfn f() { unsafe { g() } }\n";
        assert!(lint_source("rust/src/runtime/fake.rs", good).is_empty());

        // A SAFETY comment 9+ lines up does not count.
        let far = format!("// SAFETY: too far.\n{}unsafe impl Send for X {{}}\n", "\n".repeat(9));
        let f = lint_source("rust/src/runtime/fake.rs", &far);
        assert_eq!(f.len(), 1, "{f:?}");

        // `unsafe` applies in test code too.
        let in_test = "#[cfg(test)]\nmod t { fn f() { unsafe { g() } } }\n";
        assert_eq!(lint_source("rust/src/runtime/fake.rs", in_test).len(), 1);

        // ...but not as a substring of an identifier.
        let ident = "fn f() { let not_unsafe_at_all = 1; }\n";
        assert!(lint_source("rust/src/runtime/fake.rs", ident).is_empty());
    }

    #[test]
    fn bench_record_writes_confined_to_merge_helper() {
        let bad = "fn f(p: &Path) { std::fs::write(p.join(\"BENCH_runtime.json\"), b\"{}\").ok(); }\n";
        let f = lint_source("rust/benches/bench_fake.rs", bad);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, RULE_BENCH_WRITE);
        // Inside the sanctioned home it is fine.
        assert!(lint_source("rust/src/substrate/bench.rs", bad).is_empty());
        // A write that never names the record is not this rule's business.
        let other = "fn f(p: &Path) { std::fs::write(p, b\"x\").ok(); }\n";
        assert!(lint_source("rust/benches/bench_fake.rs", other).is_empty());
    }

    #[test]
    fn allow_comment_suppresses_a_reviewed_exception() {
        let src = "// rom-lint: allow(thread-spawn)\nfn f() { std::thread::spawn(|| {}); }\n";
        assert!(lint_source("rust/src/data/fake.rs", src).is_empty());
        let same_line = "fn f() { x.unwrap(); } // rom-lint: allow(coordinator-unwrap)\n";
        assert!(lint_source("rust/src/coordinator/fake.rs", same_line).is_empty());
    }

    #[test]
    fn patterns_inside_strings_do_not_trip_rules() {
        let src = "fn f() { let s = \"thread::spawn .unwrap() fs::write BENCH_runtime\"; }\n";
        assert!(lint_source("rust/src/coordinator/fake.rs", src).is_empty());
    }
}
