//! cargo bench target regenerating the paper's table11 on the scaled workload
//! (DESIGN.md §4). Reduced default budget (25 steps/variant); set
//! ROM_STEPS for the full run recorded in EXPERIMENTS.md.
fn main() {
    let rep = rom::experiments::tables::run_experiment("table11", 25)
        .expect("experiment table11 failed (run `make artifacts` first)");
    rep.print();
}
