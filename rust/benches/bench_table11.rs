//! cargo bench target regenerating the paper's table11 on the scaled workload
//! (DESIGN.md §4). Reduced default budget (25 steps/variant); set
//! ROM_STEPS for the full run recorded in EXPERIMENTS.md; set ROM_JOBS>1 to
//! fan variants out across scheduler workers (table11 measures throughput
//! and therefore always runs serially, whatever ROM_JOBS says).
fn main() {
    let jobs = rom::experiments::scheduler::default_jobs(rom::experiments::harness::dp_budget());
    let rep = rom::experiments::tables::run_experiment("table11", 25, jobs)
        .expect("experiment table11 failed (run `make artifacts` first)");
    rep.print();
}
