//! cargo bench target regenerating the paper's fig3 on the scaled workload
//! (DESIGN.md §4). Reduced default budget (80 steps/variant); set
//! ROM_STEPS for the full run recorded in EXPERIMENTS.md.
fn main() {
    let rep = rom::experiments::tables::run_experiment("fig3", 80)
        .expect("experiment fig3 failed (run `make artifacts` first)");
    rep.print();
}
