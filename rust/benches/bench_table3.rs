//! cargo bench target regenerating the paper's table3 on the scaled workload
//! (DESIGN.md §4). Reduced default budget (60 steps/variant); set
//! ROM_STEPS for the full run recorded in EXPERIMENTS.md; set ROM_JOBS>1 to
//! fan variants out across scheduler workers (rows stay byte-identical).
fn main() {
    let jobs = rom::experiments::scheduler::default_jobs(rom::experiments::harness::dp_budget());
    let rep = rom::experiments::tables::run_experiment("table3", 60, jobs)
        .expect("experiment table3 failed (run `make artifacts` first)");
    rep.print();
}
