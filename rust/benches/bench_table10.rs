//! cargo bench target regenerating the paper's table10 on the scaled workload
//! (DESIGN.md §4). Reduced default budget (60 steps/variant); set
//! ROM_STEPS for the full run recorded in EXPERIMENTS.md.
fn main() {
    let rep = rom::experiments::tables::run_experiment("table10", 60)
        .expect("experiment table10 failed (run `make artifacts` first)");
    rep.print();
}
