//! cargo bench target regenerating the paper's table6 on the scaled workload
//! (DESIGN.md §4). Reduced default budget (60 steps/variant); set
//! ROM_STEPS for the full run recorded in EXPERIMENTS.md.
fn main() {
    let rep = rom::experiments::tables::run_experiment("table6", 60)
        .expect("experiment table6 failed (run `make artifacts` first)");
    rep.print();
}
