//! Generation micro-benchmarks (§Perf): prefill-artifact latency at every
//! compiled length (`gen_prefill_L{L}_ms` — the chunk-parallel prefill
//! should cost far less per prompt token than a decode_step), one-time
//! compile cost of the generation programs, per-token decode_step latency
//! and decode throughput through the real `coordinator::generate` sampling
//! loop.
//!
//! Results merge into the same machine-readable trajectory file as
//! bench_runtime (`BENCH_runtime.json` at the repo root, override with
//! ROM_BENCH_JSON) under `gen_*` keys — read-modify-write, so running
//! either bench never clobbers the other's fields. Field-by-field schema:
//! EXPERIMENTS.md §BENCH_runtime.json schema.

use std::sync::Arc;

use rom::coordinator::generate::{generate, GenerateCfg};
use rom::data::corpus::{Corpus, CorpusSpec};
use rom::experiments::harness::artifacts_root;
use rom::runtime::artifact::Bundle;
use rom::runtime::session::Session;
use rom::runtime::tensor::Tensor;
use rom::substrate::bench::{bench, bench_json_path, env_u64, merge_bench_json, time_once};
use rom::substrate::json::Json;

fn main() {
    let variant = std::env::var("ROM_BENCH_VARIANT").unwrap_or_else(|_| "rom-tiny".into());
    if !artifacts_root().join(&variant).join("manifest.json").exists() {
        eprintln!("artifacts/{variant} missing — run `make artifacts`");
        return;
    }
    let bundle = Bundle::open(artifacts_root().join(&variant)).unwrap();
    let Some(spec) = bundle.manifest.decode.clone() else {
        eprintln!("artifacts/{variant} has no decode artifacts — re-run `make artifacts`");
        return;
    };
    let ctx = bundle.manifest.eval_lens[0]; // shortest prefill artifact
    println!(
        "== generation micro-benches on {variant} (batch {}, prompt {ctx}) ==",
        spec.batch
    );

    // One-time compile latencies for the generation programs.
    let (_, t_prefill) = time_once(|| bundle.prefill(ctx).unwrap());
    println!("compile prefill_L{ctx}: {t_prefill:.2}s");
    let (_, t_decode) = time_once(|| bundle.decode_step().unwrap());
    println!("compile decode_step:    {t_decode:.2}s");

    let sess = Session::init(Arc::clone(&bundle), 0).unwrap();
    let corpus = Corpus::new(CorpusSpec::default(), 17);
    let prompts: Vec<Vec<i32>> = (0..spec.batch as u64)
        .map(|r| corpus.generate(0xBE9C_0000 + r, ctx))
        .collect();

    // Prompt consumption through every fused prefill artifact: one device
    // call each, parallel in L, so per-prompt-token cost should FALL as L
    // grows. (L, median ms, prompt tokens/s) per artifact length.
    let mut lens = spec.prefill_lens.clone();
    lens.sort_unstable();
    let mut prefill_rows: Vec<(usize, f64, f64)> = Vec::new();
    for &l in &lens {
        let mut flat = Vec::with_capacity(spec.batch * l);
        for r in 0..spec.batch as u64 {
            flat.extend_from_slice(&corpus.generate(0xBE9C_0000 + r, l));
        }
        let prompt_batch = Tensor::i32(&[spec.batch, l], flat);
        let stats = bench(&format!("prefill_L{l} (one device call)"), 1, 8, || {
            std::hint::black_box(sess.prefill(&prompt_batch).unwrap());
        });
        let ms = stats.median_secs() * 1e3;
        let tps = (spec.batch * l) as f64 / stats.median_secs();
        println!("prefill_L{l}: {ms:.2} ms median, {tps:.0} prompt tokens/s");
        prefill_rows.push((l, ms, tps));
    }
    let &(_, prefill_ms_shortest, _) = prefill_rows.first().unwrap();
    let &(longest, longest_ms, prefill_tps) = prefill_rows.last().unwrap();

    // Per-token decode latency and throughput through the real sampling
    // loop (the numbers `rom generate` prints).
    let max_new = (env_u64("ROM_GEN_TOKENS", 64) as usize).max(2);
    let cfg = GenerateCfg { max_new, temperature: 0.8, top_k: 8, seed: 0 };
    let (report, gen_s) = time_once(|| generate(&sess, &prompts, &cfg).unwrap());
    let decode_ms = report.median_decode_ms().expect("max_new > 1");
    let decode_tps = report.decode_tokens_per_sec().expect("max_new > 1");
    let device_rps = report.device_rows_per_sec().expect("max_new > 1");
    println!(
        "decode_step: {decode_ms:.2} ms/step median -> {decode_tps:.0} tokens/s \
         effective, {device_rps:.0} rows/s device \
         ({} rows x {} steps in {gen_s:.2}s end-to-end)",
        spec.batch,
        max_new - 1
    );

    // Per-token cost of prompt consumption vs decoding, at the longest
    // artifact: the ratio the chunk-parallel prefill exists to shrink.
    let prefill_per_token_ms = longest_ms / longest as f64;
    let ratio = prefill_per_token_ms / decode_ms;
    println!(
        "prefill_L{longest} per prompt token: {prefill_per_token_ms:.4} ms \
         ({ratio:.3}x a decode_step)"
    );

    // Merge the gen_* fields into the shared trajectory record — through the
    // atomic helper, so a concurrent bench_runtime (or a crash mid-write)
    // can never cost us the other bench's fields.
    let path = bench_json_path();
    let mut fields: Vec<(String, Json)> = vec![
        ("gen_variant".into(), Json::str(variant.as_str())),
        ("gen_batch".into(), Json::num(spec.batch as f64)),
        ("gen_prompt_len".into(), Json::num(ctx as f64)),
        ("gen_max_new".into(), Json::num(max_new as f64)),
        ("gen_compile_prefill_s".into(), Json::num(t_prefill)),
        ("gen_compile_decode_s".into(), Json::num(t_decode)),
        ("gen_prefill_ms".into(), Json::num(prefill_ms_shortest)),
        ("gen_prefill_tokens_per_sec".into(), Json::num(prefill_tps)),
        ("gen_prefill_per_token_vs_decode".into(), Json::num(ratio)),
        ("gen_decode_step_ms".into(), Json::num(decode_ms)),
        ("gen_decode_tokens_per_sec".into(), Json::num(decode_tps)),
        ("gen_decode_device_rows_per_sec".into(), Json::num(device_rps)),
    ];
    for &(l, ms, _) in &prefill_rows {
        fields.push((format!("gen_prefill_L{l}_ms"), Json::num(ms)));
    }
    // Full-attention layouts carry a capped KV lane; record the capacity so
    // trajectory diffs can tell cache-bound decode rates from unbounded ones.
    if let Some(cap) = spec.kv_cap {
        fields.push(("gen_kv_cap".into(), Json::num(cap as f64)));
    }
    merge_bench_json(&path, |map| {
        for (k, v) in fields {
            map.insert(k, v);
        }
    })
    .unwrap();
    println!("merged gen_* fields into {}", path.display());
}
