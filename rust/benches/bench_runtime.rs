//! Runtime micro-benchmarks (§Perf): artifact compile latency, fused-step
//! latency, eval latency, host<->literal conversion cost, and the grad-accum
//! path vs the fused path. These are the numbers the L3 optimization loop
//! iterates against (EXPERIMENTS.md §Perf).

use rom::data::corpus::{Corpus, CorpusSpec};
use rom::data::loader::Loader;
use rom::experiments::harness::artifacts_root;
use rom::runtime::artifact::{cpu_client, Bundle};
use rom::runtime::session::Session;
use rom::runtime::tensor::Tensor;
use rom::substrate::bench::{bench, time_once};

fn main() {
    let variant = std::env::var("ROM_BENCH_VARIANT").unwrap_or_else(|_| "rom-tiny".into());
    if !artifacts_root().join(&variant).join("manifest.json").exists() {
        eprintln!("artifacts/{variant} missing — run `make artifacts`");
        return;
    }
    let client = cpu_client().unwrap();
    let bundle = Bundle::load(client, artifacts_root().join(&variant)).unwrap();
    let man = bundle.manifest.clone();
    println!("== runtime micro-benches on {variant} ==");

    // One-time compile latencies.
    let (_, t_init) = time_once(|| bundle.init().unwrap());
    println!("compile init:  {t_init:.2}s");
    let (_, t_step) = time_once(|| bundle.step().unwrap());
    println!("compile step:  {t_step:.2}s");
    let (_, t_eval) = time_once(|| bundle.eval(man.eval_lens[0]).unwrap());
    println!("compile eval:  {t_eval:.2}s");

    let mut sess = Session::init(&bundle, 0).unwrap();
    let corpus = Corpus::new(CorpusSpec::default(), 17);
    let stream = corpus.generate(0, 64 * man.batch_size * (man.seq_len + 1));
    let mut loader = Loader::new(stream, man.batch_size, man.seq_len, 0);

    // Fused train step.
    let batch = loader.next_batch();
    let s = bench("fused train_step", 2, 12, || {
        sess.train_step(1e-3, &batch.tokens, &batch.targets).unwrap();
    });
    let toks = (man.batch_size * man.seq_len) as f64;
    println!(
        "  -> {:.0} tokens/s steady-state",
        toks / s.median_secs()
    );

    // Grad-accum path (2 microbatches) for the same global batch.
    if man.batch_size % man.micro_batch == 0 {
        let micro = Loader::split_micro(&batch, man.micro_batch);
        bench("grad-accum step (micro path)", 1, 6, || {
            sess.train_step_accum(1e-3, &micro).unwrap();
        });
    }

    // Eval at the shortest length.
    let ctx = man.eval_lens[0];
    let held = corpus.generate(1234, ctx + 1);
    let tok = Tensor::i32(&[1, ctx], held[..ctx].to_vec());
    let tgt = Tensor::i32(&[1, ctx], held[1..ctx + 1].to_vec());
    bench("eval (1 seq)", 2, 12, || {
        sess.eval(ctx, &tok, &tgt).unwrap();
    });

    // Host-side costs the step pays per iteration.
    bench("batch assembly (loader)", 5, 200, || {
        std::hint::black_box(loader.next_batch());
    });
    bench("tensor->literal (tokens)", 5, 200, || {
        std::hint::black_box(batch.tokens.to_literal().unwrap());
    });
    let (params, _, _) = sess.export().unwrap();
    let total: usize = params.iter().map(|p| p.len()).sum();
    let s = bench("state export (checkpoint copy)", 1, 6, || {
        std::hint::black_box(sess.export().unwrap());
    });
    println!(
        "  -> {:.1} MB state, {:.0} MB/s",
        total as f64 * 4.0 / 1e6,
        total as f64 * 4.0 / 1e6 / s.median_secs()
    );
}
