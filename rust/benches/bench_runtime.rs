//! Runtime micro-benchmarks (§Perf): artifact compile latency, fused-step
//! latency, eval latency, host<->literal conversion cost, the grad-accum
//! path vs the fused path, checkpoint save/load, the parallel variant
//! sweep (serial vs scheduler workers), data-parallel training (dp=1
//! baseline vs dp=K replicas with host-side gradient reduction), and the
//! continuous-batching serve loop (admission-to-first-token and per-token
//! service latency). These are the numbers the L3 optimization loop
//! iterates against (EXPERIMENTS.md §Perf L3 log).
//!
//! Besides the human-readable report, this bench emits machine-readable
//! `BENCH_runtime.json` at the repo root (override the path with
//! ROM_BENCH_JSON) so subsequent PRs can track the perf trajectory:
//! steady-state tokens/sec (first-step XLA compile excluded by warmup),
//! checkpoint save/load wall time, sweep wall-clock + speedup, and peak
//! host RSS.

use std::sync::Arc;

use rom::config::TrainCfg;
use rom::coordinator::checkpoint::Checkpoint;
use rom::coordinator::serve::{Engine, FinishReason, Request as ServeRequest, ServeCfg, Submit};
use rom::coordinator::trainer::{TrainReport, Trainer};
use rom::data::corpus::{Corpus, CorpusSpec};
use rom::data::loader::Loader;
use rom::experiments::harness::{artifacts_root, have_variant, RunSpec};
use rom::experiments::scheduler::run_sweep;
use rom::runtime::artifact::Bundle;
use rom::runtime::session::Session;
use rom::runtime::tensor::Tensor;
use rom::substrate::bench::{bench, bench_json_path, env_u64, merge_bench_json, time_once};
use rom::substrate::json::Json;

/// Peak resident set size in bytes (linux VmHWM); None elsewhere.
fn peak_rss_bytes() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = status.lines().find(|l| l.starts_with("VmHWM:"))?;
    let kb: u64 = line.split_whitespace().nth(1)?.parse().ok()?;
    Some(kb * 1024)
}

fn main() {
    let variant = std::env::var("ROM_BENCH_VARIANT").unwrap_or_else(|_| "rom-tiny".into());
    if !artifacts_root().join(&variant).join("manifest.json").exists() {
        eprintln!("artifacts/{variant} missing — run `make artifacts`");
        return;
    }
    let bundle = Bundle::open(artifacts_root().join(&variant)).unwrap();
    let man = bundle.manifest.clone();
    println!("== runtime micro-benches on {variant} ==");

    // One-time compile latencies.
    let (_, t_init) = time_once(|| bundle.init().unwrap());
    println!("compile init:  {t_init:.2}s");
    let (_, t_step) = time_once(|| bundle.step().unwrap());
    println!("compile step:  {t_step:.2}s");
    let (_, t_eval) = time_once(|| bundle.eval(man.eval_lens[0]).unwrap());
    println!("compile eval:  {t_eval:.2}s");

    let mut sess = Session::init(Arc::clone(&bundle), 0).unwrap();
    let corpus = Corpus::new(CorpusSpec::default(), 17);
    let stream = corpus.generate(0, 64 * man.batch_size * (man.seq_len + 1));
    let mut loader = Loader::new(stream, man.batch_size, man.seq_len, 0);

    // Fused train step on pre-encoded literals — the pipelined hot path.
    // Warmup iterations absorb the first-step compile/transfer, so the
    // reported median is steady-state.
    let batch = loader.next_batch();
    let tok_lit = batch.tokens.to_literal().unwrap();
    let tgt_lit = batch.targets.to_literal().unwrap();
    let fused_s = bench("fused train_step (device literals)", 2, 12, || {
        sess.train_step_device(1e-3, &tok_lit, &tgt_lit, false).unwrap();
    });
    let toks = (man.batch_size * man.seq_len) as f64;
    let steady_tps = toks / fused_s.median_secs();
    println!("  -> {steady_tps:.0} tokens/s steady-state");

    // Telemetry decode overhead (the cost the sampled decode avoids).
    bench("fused train_step (+router decode)", 1, 6, || {
        sess.train_step_device(1e-3, &tok_lit, &tgt_lit, true).unwrap();
    });

    // Grad-accum path for the same global batch, microbatches pre-encoded.
    let mut accum_median_s = None;
    if man.batch_size % man.micro_batch == 0 {
        let micro = Loader::split_micro(&batch, man.micro_batch);
        let lits: Vec<(xla::Literal, xla::Literal)> = micro
            .iter()
            .map(|m| {
                (
                    rom::runtime::tensor::literal_from_i32(&m.shape(), m.tokens).unwrap(),
                    rom::runtime::tensor::literal_from_i32(&m.shape(), m.targets).unwrap(),
                )
            })
            .collect();
        let refs: Vec<(&xla::Literal, &xla::Literal)> =
            lits.iter().map(|(t, g)| (t, g)).collect();
        let s = bench("grad-accum step (micro path)", 1, 6, || {
            sess.train_step_accum_device(1e-3, &refs, false).unwrap();
        });
        accum_median_s = Some(s.median_secs());
    }

    // Eval at the shortest length.
    let ctx = man.eval_lens[0];
    let held = corpus.generate(1234, ctx + 1);
    let tok = Tensor::i32(&[1, ctx], held[..ctx].to_vec());
    let tgt = Tensor::i32(&[1, ctx], held[1..ctx + 1].to_vec());
    bench("eval (1 seq)", 2, 12, || {
        sess.eval(ctx, &tok, &tgt).unwrap();
    });

    // Host-side costs the step loop no longer pays inline (both stages now
    // run on the prefetch pipeline's background threads).
    bench("batch assembly (loader)", 5, 200, || {
        std::hint::black_box(loader.next_batch());
    });
    bench("tensor->literal (tokens)", 5, 200, || {
        std::hint::black_box(batch.tokens.to_literal().unwrap());
    });
    let (params, m, v) = sess.export().unwrap();
    let total: usize = params.iter().map(|p| p.len()).sum();
    let export_s = bench("state export (checkpoint copy)", 1, 6, || {
        std::hint::black_box(sess.export().unwrap());
    });
    println!(
        "  -> {:.1} MB state, {:.0} MB/s",
        total as f64 * 4.0 / 1e6,
        total as f64 * 4.0 / 1e6 / export_s.median_secs()
    );

    // Checkpoint save/load through the streaming writer.
    let dir = std::env::temp_dir().join("rom_bench_ckpt");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(format!("{variant}.ckpt"));
    let ck = Checkpoint { step: sess.step_count(), params, m, v };
    let save_s = bench("checkpoint save (streamed)", 1, 6, || {
        ck.save(&path).unwrap();
    });
    let load_s = bench("checkpoint load (streamed)", 1, 6, || {
        std::hint::black_box(Checkpoint::load(&path).unwrap());
    });
    let ckpt_bytes = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
    let _ = std::fs::remove_file(&path);

    // Snapshot the single-session high-water RSS BEFORE the sweep section:
    // the sweep runs 8 extra training jobs with their own clients, and the
    // trajectory field must keep measuring the hot path it always measured.
    let single_session_rss = peak_rss_bytes();

    // Parallel variant sweep: the experiment scheduler's wall-clock win.
    // >= 4 short training jobs (cycling the available variants), serial vs
    // ROM_SWEEP_JOBS workers (default 2 — the speedup bound of a 2-core
    // box). Each job opens its own PJRT client and compiles its own
    // programs, so compile latency parallelizes along with training.
    let mut sweep_fields: Vec<(&str, Json)> = Vec::new();
    {
        let candidates =
            ["rom-tiny", "mamba-tiny", "samba-e2", "rom-small", "mamba-small", "samba-e2-rom"];
        let avail: Vec<String> =
            candidates.iter().filter(|n| have_variant(n)).map(|s| s.to_string()).collect();
        if avail.is_empty() {
            eprintln!("sweep section skipped: no sweep candidate artifacts present");
        } else {
            let sweep_steps = env_u64("ROM_SWEEP_STEPS", 12);
            // Honor the operator's worker count exactly (ROM_SWEEP_JOBS=1
            // records an honest 1.0x baseline); only 0 is clamped.
            let sweep_jobs = env_u64("ROM_SWEEP_JOBS", 2).max(1) as usize;
            // 4 jobs keeps the section's wall-clock bounded while exercising
            // queueing (more jobs than workers); cycle the available variants.
            let n_jobs = env_u64("ROM_SWEEP_NUM_JOBS", 4).max(2) as usize;
            let variants: Vec<String> =
                (0..n_jobs).map(|i| avail[i % avail.len()].clone()).collect();
            let mut spec = RunSpec::new(sweep_steps, 3e-3);
            spec.final_eval = false;
            spec.quiet = true;
            println!(
                "== parallel sweep: {n_jobs} jobs x {sweep_steps} steps over {:?} ==",
                avail
            );
            let (serial_res, serial_s) = time_once(|| run_sweep(&variants, &spec, 1));
            let (par_res, par_s) = time_once(|| run_sweep(&variants, &spec, sweep_jobs));
            // A failed sweep job must not panic the bench: the trajectory
            // JSON written below is the deliverable, so report the failure
            // and skip only the sweep fields (the scheduler's own error
            // isolation, applied here too).
            let errors: Vec<String> = serial_res
                .iter()
                .chain(par_res.iter())
                .filter_map(|r| r.as_ref().err().map(|e| format!("{e:#}")))
                .collect();
            // Nondeterminism gets the same isolation as job errors: report
            // loudly, omit only the sweep fields, and keep the rest of the
            // trajectory JSON (the tests are where a mismatch hard-fails).
            let mismatches: Vec<String> = if errors.is_empty() {
                serial_res
                    .iter()
                    .zip(par_res.iter())
                    .filter_map(|(a, b)| {
                        let (a, b) = (a.as_ref().unwrap(), b.as_ref().unwrap());
                        (a.final_loss.to_bits() != b.final_loss.to_bits()).then(|| {
                            format!(
                                "{}: serial {} vs parallel {}",
                                a.name, a.final_loss, b.final_loss
                            )
                        })
                    })
                    .collect()
            } else {
                Vec::new()
            };
            if errors.is_empty() && mismatches.is_empty() {
                let speedup = serial_s / par_s.max(1e-9);
                println!(
                    "sweep serial {serial_s:.2}s, {sweep_jobs}-worker {par_s:.2}s \
                     -> {speedup:.2}x (losses bit-identical)"
                );
                sweep_fields.push(("sweep_num_jobs", Json::num(n_jobs as f64)));
                sweep_fields.push(("sweep_steps_per_job", Json::num(sweep_steps as f64)));
                sweep_fields.push(("sweep_workers", Json::num(sweep_jobs as f64)));
                sweep_fields.push(("sweep_serial_s", Json::num(serial_s)));
                sweep_fields.push(("sweep_parallel_s", Json::num(par_s)));
                sweep_fields.push(("sweep_speedup", Json::num(speedup)));
                // Process-lifetime peak including the sweep's worker clients
                // (distinct from peak_rss_bytes, which excludes the sweep).
                if let Some(rss) = peak_rss_bytes() {
                    sweep_fields.push(("sweep_peak_rss_bytes", Json::num(rss as f64)));
                }
            } else if errors.is_empty() {
                eprintln!(
                    "sweep section omitted from BENCH json: {} determinism mismatch(es)",
                    mismatches.len()
                );
                for e in &mismatches {
                    eprintln!("  sweep: {e}");
                }
            } else {
                // Determinism was NOT compared — job failures preempt it.
                eprintln!(
                    "sweep section omitted from BENCH json: {} job error(s) (determinism not compared)",
                    errors.len()
                );
                for e in &errors {
                    eprintln!("  sweep: {e}");
                }
            }
        }
    }

    // Data-parallel training: the dp driver's scaling win. dp=1 baseline
    // vs dp=ROM_DP_WORLD replicas at the SAME global batch — the reduced
    // per-replica shard plus host-side rank-ordered gradient reduction must
    // produce bit-identical losses, so the comparison below is pure
    // throughput. A mismatch or a failed run reports loudly and omits only
    // the dp_* fields (same isolation as the sweep section above).
    let mut dp_fields: Vec<(&str, Json)> = Vec::new();
    {
        let dp_world = env_u64("ROM_DP_WORLD", 2).max(2) as usize;
        let dp_steps = env_u64("ROM_DP_STEPS", 12).max(1);
        if man.batch_size % dp_world != 0 {
            eprintln!(
                "dp section skipped: batch {} not divisible by dp world {dp_world}",
                man.batch_size
            );
        } else {
            println!("== data-parallel: dp=1 vs dp={dp_world}, {dp_steps} steps ==");
            let run_dp = |world: usize| -> anyhow::Result<TrainReport> {
                let cfg = TrainCfg {
                    steps: dp_steps,
                    max_lr: 3e-3,
                    log_every: 0,
                    ..TrainCfg::default()
                };
                let mut t = Trainer::new(Arc::clone(&bundle), cfg);
                t.quiet = true;
                t.final_eval = false;
                t.dp = Some(world);
                t.run()
            };
            match (run_dp(1), run_dp(dp_world)) {
                (Ok(base), Ok(par)) => {
                    if base.final_loss.to_bits() != par.final_loss.to_bits() {
                        eprintln!(
                            "dp section omitted from BENCH json: determinism mismatch \
                             (dp=1 loss {} vs dp={dp_world} loss {})",
                            base.final_loss, par.final_loss
                        );
                    } else {
                        let speedup = par.tokens_per_sec / base.tokens_per_sec.max(1e-9);
                        println!(
                            "dp=1 {:.0} tok/s, dp={dp_world} {:.0} tok/s -> {speedup:.2}x \
                             (losses bit-identical)",
                            base.tokens_per_sec, par.tokens_per_sec
                        );
                        dp_fields.push(("dp_world", Json::num(dp_world as f64)));
                        dp_fields
                            .push(("dp_baseline_tokens_per_sec", Json::num(base.tokens_per_sec)));
                        dp_fields.push(("dp_tokens_per_sec", Json::num(par.tokens_per_sec)));
                        dp_fields.push(("dp_speedup", Json::num(speedup)));
                        if let Some(st) = &par.dp_stats {
                            dp_fields.push(("dp_shard_step_ms", Json::num(st.shard_step_ms)));
                            dp_fields.push(("dp_reduce_ms", Json::num(st.reduce_ms)));
                        }
                    }
                }
                (base, par) => {
                    eprintln!("dp section omitted from BENCH json: dp run(s) failed");
                    for (tag, res) in [("dp=1".to_string(), base), (format!("dp={dp_world}"), par)] {
                        if let Err(e) = res {
                            eprintln!("  {tag}: {e:#}");
                        }
                    }
                }
            }
        }
    }

    // Continuous-batching serve loop: queue wait, TTFT and per-token
    // service latency through the real `coordinator::serve` engine
    // (skipped when the variant ships no decode artifacts). More requests
    // than slots, so slot turnover/swap-in is actually exercised.
    let mut serve_fields: Vec<(&str, Json)> = Vec::new();
    if let Some(dspec) = &man.decode {
        let n_req =
            env_u64("ROM_SERVE_REQUESTS", 2 * dspec.batch as u64 + 1).max(1) as usize;
        let serve_new = (env_u64("ROM_SERVE_TOKENS", 16) as usize).max(1);
        println!(
            "== serve: {n_req} requests x {serve_new} tokens (batch {}) ==",
            dspec.batch
        );
        let mut engine = Engine::new(&sess, &ServeCfg { queue_cap: n_req }).unwrap();
        let mut responses = Vec::new();
        let (_, serve_s) = time_once(|| {
            for i in 0..n_req as u64 {
                let req = ServeRequest {
                    prompt: corpus.generate(0x5E87_0000 + i, ctx),
                    max_new: serve_new,
                    temperature: 0.8,
                    top_k: 8,
                    seed: i,
                    stop: None,
                };
                match engine.submit(req).unwrap() {
                    Submit::Accepted(_) => {}
                    Submit::Rejected(_) => unreachable!("queue sized to n_req"),
                }
            }
            responses = engine.drain(&sess).unwrap();
        });
        let rep = engine.report();
        let serve_tps = rep.emitted_tokens as f64 / serve_s.max(1e-9);
        println!(
            "serve: {} tokens in {serve_s:.2}s -> {serve_tps:.0} tokens/s \
             ({} prefills, {} decode steps)",
            rep.emitted_tokens, rep.prefills, rep.decode_steps
        );
        serve_fields.push(("serve_requests", Json::num(n_req as f64)));
        serve_fields.push(("serve_batch", Json::num(dspec.batch as f64)));
        serve_fields.push(("serve_max_new", Json::num(serve_new as f64)));
        serve_fields.push(("serve_tokens_per_sec", Json::num(serve_tps)));
        serve_fields.push(("serve_prefills", Json::num(rep.prefills as f64)));
        // Full-attention layouts can cut requests short at the KV cap; the
        // count distinguishes "slow" from "truncated" in trajectory diffs.
        let exhausted = responses
            .iter()
            .filter(|r| r.finish == FinishReason::KvCapExhausted)
            .count();
        serve_fields.push(("serve_kv_cap_exhausted", Json::num(exhausted as f64)));
        if let Some(q) = &rep.queue_wait {
            serve_fields.push(("serve_queue_wait_ms_p50", Json::num(q.p50_ms)));
        }
        if let Some(t) = &rep.ttft {
            serve_fields.push(("serve_ttft_ms_p50", Json::num(t.p50_ms)));
            serve_fields.push(("serve_ttft_ms_p90", Json::num(t.p90_ms)));
        }
        if let Some(t) = &rep.per_token {
            serve_fields.push(("serve_token_ms_p50", Json::num(t.p50_ms)));
        }
    } else {
        eprintln!("serve section skipped: no decode artifacts for {variant}");
    }

    // Machine-readable trajectory record.
    let mut fields = vec![
        ("variant", Json::str(variant.as_str())),
        ("steady_state_tokens_per_sec", Json::num(steady_tps)),
        ("fused_step_ms", Json::num(s_ms(fused_s.median_secs()))),
        ("checkpoint_save_ms", Json::num(s_ms(save_s.median_secs()))),
        ("checkpoint_load_ms", Json::num(s_ms(load_s.median_secs()))),
        ("checkpoint_bytes", Json::num(ckpt_bytes as f64)),
        ("compile_init_s", Json::num(t_init)),
        ("compile_step_s", Json::num(t_step)),
    ];
    if let Some(a) = accum_median_s {
        fields.push(("grad_accum_step_ms", Json::num(s_ms(a))));
    }
    fields.extend(sweep_fields);
    fields.extend(dp_fields);
    fields.extend(serve_fields);
    if let Some(rss) = single_session_rss {
        fields.push(("peak_rss_bytes", Json::num(rss as f64)));
    }
    // This bench owns every non-gen_* field and rewrites them wholesale
    // (stale sweep_*/serve_* keys from a previous run must not linger), but
    // the gen_* keys belong to bench_generate and survive — the atomic
    // helper guarantees a concurrent bench or a crash mid-write can never
    // clobber them.
    let out_path = bench_json_path();
    merge_bench_json(&out_path, |map| {
        map.retain(|k, _| k.starts_with("gen_"));
        for (k, v) in fields {
            map.insert(k.to_string(), v);
        }
    })
    .unwrap();
    println!("wrote {}", out_path.display());
}

fn s_ms(secs: f64) -> f64 {
    secs * 1e3
}
