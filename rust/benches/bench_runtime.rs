//! Runtime micro-benchmarks (§Perf): artifact compile latency, fused-step
//! latency, eval latency, host<->literal conversion cost, the grad-accum
//! path vs the fused path, and checkpoint save/load. These are the numbers
//! the L3 optimization loop iterates against (EXPERIMENTS.md §Perf L3 log).
//!
//! Besides the human-readable report, this bench emits machine-readable
//! `BENCH_runtime.json` at the repo root (override the path with
//! ROM_BENCH_JSON) so subsequent PRs can track the perf trajectory:
//! steady-state tokens/sec (first-step XLA compile excluded by warmup),
//! checkpoint save/load wall time, and peak host RSS.

use std::path::PathBuf;

use rom::coordinator::checkpoint::Checkpoint;
use rom::data::corpus::{Corpus, CorpusSpec};
use rom::data::loader::Loader;
use rom::experiments::harness::artifacts_root;
use rom::runtime::artifact::{cpu_client, Bundle};
use rom::runtime::session::Session;
use rom::runtime::tensor::Tensor;
use rom::substrate::bench::{bench, time_once};
use rom::substrate::json::Json;

/// Peak resident set size in bytes (linux VmHWM); None elsewhere.
fn peak_rss_bytes() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = status.lines().find(|l| l.starts_with("VmHWM:"))?;
    let kb: u64 = line.split_whitespace().nth(1)?.parse().ok()?;
    Some(kb * 1024)
}

fn bench_json_path() -> PathBuf {
    if let Ok(p) = std::env::var("ROM_BENCH_JSON") {
        return PathBuf::from(p);
    }
    // CARGO_MANIFEST_DIR is <repo>/rust; the trajectory file lives at the
    // repo root next to ROADMAP.md.
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../BENCH_runtime.json")
}

fn main() {
    let variant = std::env::var("ROM_BENCH_VARIANT").unwrap_or_else(|_| "rom-tiny".into());
    if !artifacts_root().join(&variant).join("manifest.json").exists() {
        eprintln!("artifacts/{variant} missing — run `make artifacts`");
        return;
    }
    let client = cpu_client().unwrap();
    let bundle = Bundle::load(client, artifacts_root().join(&variant)).unwrap();
    let man = bundle.manifest.clone();
    println!("== runtime micro-benches on {variant} ==");

    // One-time compile latencies.
    let (_, t_init) = time_once(|| bundle.init().unwrap());
    println!("compile init:  {t_init:.2}s");
    let (_, t_step) = time_once(|| bundle.step().unwrap());
    println!("compile step:  {t_step:.2}s");
    let (_, t_eval) = time_once(|| bundle.eval(man.eval_lens[0]).unwrap());
    println!("compile eval:  {t_eval:.2}s");

    let mut sess = Session::init(&bundle, 0).unwrap();
    let corpus = Corpus::new(CorpusSpec::default(), 17);
    let stream = corpus.generate(0, 64 * man.batch_size * (man.seq_len + 1));
    let mut loader = Loader::new(stream, man.batch_size, man.seq_len, 0);

    // Fused train step on pre-encoded literals — the pipelined hot path.
    // Warmup iterations absorb the first-step compile/transfer, so the
    // reported median is steady-state.
    let batch = loader.next_batch();
    let tok_lit = batch.tokens.to_literal().unwrap();
    let tgt_lit = batch.targets.to_literal().unwrap();
    let fused_s = bench("fused train_step (device literals)", 2, 12, || {
        sess.train_step_device(1e-3, &tok_lit, &tgt_lit, false).unwrap();
    });
    let toks = (man.batch_size * man.seq_len) as f64;
    let steady_tps = toks / fused_s.median_secs();
    println!("  -> {steady_tps:.0} tokens/s steady-state");

    // Telemetry decode overhead (the cost the sampled decode avoids).
    bench("fused train_step (+router decode)", 1, 6, || {
        sess.train_step_device(1e-3, &tok_lit, &tgt_lit, true).unwrap();
    });

    // Grad-accum path for the same global batch, microbatches pre-encoded.
    let mut accum_median_s = None;
    if man.batch_size % man.micro_batch == 0 {
        let micro = Loader::split_micro(&batch, man.micro_batch);
        let lits: Vec<(xla::Literal, xla::Literal)> = micro
            .iter()
            .map(|m| {
                (
                    rom::runtime::tensor::literal_from_i32(&m.shape(), m.tokens).unwrap(),
                    rom::runtime::tensor::literal_from_i32(&m.shape(), m.targets).unwrap(),
                )
            })
            .collect();
        let refs: Vec<(&xla::Literal, &xla::Literal)> =
            lits.iter().map(|(t, g)| (t, g)).collect();
        let s = bench("grad-accum step (micro path)", 1, 6, || {
            sess.train_step_accum_device(1e-3, &refs).unwrap();
        });
        accum_median_s = Some(s.median_secs());
    }

    // Eval at the shortest length.
    let ctx = man.eval_lens[0];
    let held = corpus.generate(1234, ctx + 1);
    let tok = Tensor::i32(&[1, ctx], held[..ctx].to_vec());
    let tgt = Tensor::i32(&[1, ctx], held[1..ctx + 1].to_vec());
    bench("eval (1 seq)", 2, 12, || {
        sess.eval(ctx, &tok, &tgt).unwrap();
    });

    // Host-side costs the step loop no longer pays inline (both stages now
    // run on the prefetch pipeline's background threads).
    bench("batch assembly (loader)", 5, 200, || {
        std::hint::black_box(loader.next_batch());
    });
    bench("tensor->literal (tokens)", 5, 200, || {
        std::hint::black_box(batch.tokens.to_literal().unwrap());
    });
    let (params, m, v) = sess.export().unwrap();
    let total: usize = params.iter().map(|p| p.len()).sum();
    let export_s = bench("state export (checkpoint copy)", 1, 6, || {
        std::hint::black_box(sess.export().unwrap());
    });
    println!(
        "  -> {:.1} MB state, {:.0} MB/s",
        total as f64 * 4.0 / 1e6,
        total as f64 * 4.0 / 1e6 / export_s.median_secs()
    );

    // Checkpoint save/load through the streaming writer.
    let dir = std::env::temp_dir().join("rom_bench_ckpt");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(format!("{variant}.ckpt"));
    let ck = Checkpoint { step: sess.step_count(), params, m, v };
    let save_s = bench("checkpoint save (streamed)", 1, 6, || {
        ck.save(&path).unwrap();
    });
    let load_s = bench("checkpoint load (streamed)", 1, 6, || {
        std::hint::black_box(Checkpoint::load(&path).unwrap());
    });
    let ckpt_bytes = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
    let _ = std::fs::remove_file(&path);

    // Machine-readable trajectory record.
    let mut fields = vec![
        ("variant", Json::str(variant.as_str())),
        ("steady_state_tokens_per_sec", Json::num(steady_tps)),
        ("fused_step_ms", Json::num(s_ms(fused_s.median_secs()))),
        ("checkpoint_save_ms", Json::num(s_ms(save_s.median_secs()))),
        ("checkpoint_load_ms", Json::num(s_ms(load_s.median_secs()))),
        ("checkpoint_bytes", Json::num(ckpt_bytes as f64)),
        ("compile_init_s", Json::num(t_init)),
        ("compile_step_s", Json::num(t_step)),
    ];
    if let Some(a) = accum_median_s {
        fields.push(("grad_accum_step_ms", Json::num(s_ms(a))));
    }
    if let Some(rss) = peak_rss_bytes() {
        fields.push(("peak_rss_bytes", Json::num(rss as f64)));
    }
    let out_path = bench_json_path();
    std::fs::write(&out_path, Json::obj(fields).to_string()).unwrap();
    println!("wrote {}", out_path.display());
}

fn s_ms(secs: f64) -> f64 {
    secs * 1e3
}
