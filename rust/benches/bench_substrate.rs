//! Substrate micro-benchmarks: JSON parse/serialize, RNG throughput, corpus
//! generation, loader batching, checkpoint IO. Establishes that L3 host-side
//! work is far off the training hot path's critical cost (§Perf).

use rom::coordinator::checkpoint::Checkpoint;
use rom::data::corpus::{Corpus, CorpusSpec};
use rom::data::loader::Loader;
use rom::runtime::tensor::Tensor;
use rom::substrate::bench::bench;
use rom::substrate::json::Json;
use rom::substrate::rng::Rng;

fn main() {
    println!("== substrate micro-benches ==");

    // RNG throughput.
    let mut rng = Rng::new(1);
    let s = bench("rng 1M u64", 2, 20, || {
        let mut acc = 0u64;
        for _ in 0..1_000_000 {
            acc ^= rng.next_u64();
        }
        std::hint::black_box(acc);
    });
    println!("  -> {:.0} M u64/s", 1.0 / s.median_secs() / 1e6 * 1e6 / 1e6 * 1_000_000.0 / 1e6);

    // Corpus generation.
    let corpus = Corpus::new(CorpusSpec::default(), 1);
    let s = bench("corpus generate 100k tokens", 1, 10, || {
        std::hint::black_box(corpus.generate(7, 100_000));
    });
    println!("  -> {:.1} M tokens/s", 0.1 / s.median_secs());

    // Loader batching.
    let stream = corpus.generate(0, 2_000_000);
    let mut loader = Loader::new(stream, 8, 128, 0);
    bench("loader next_batch 8x128", 10, 500, || {
        std::hint::black_box(loader.next_batch());
    });

    // JSON.
    let mut obj = vec![];
    for i in 0..200 {
        obj.push((format!("key_{i}"), Json::Num(i as f64)));
    }
    let doc = Json::Obj(obj.into_iter().collect()).to_string();
    bench("json parse 200-key object", 5, 300, || {
        std::hint::black_box(Json::parse(&doc).unwrap());
    });

    // Checkpoint round-trip (1 MB state).
    let tensors: Vec<Tensor> = (0..16)
        .map(|i| Tensor::f32(&[128, 128], vec![i as f32; 128 * 128]))
        .collect();
    let ck = Checkpoint { step: 1, params: tensors.clone(), m: tensors.clone(), v: tensors };
    let dir = std::env::temp_dir().join("rom_bench_ckpt");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("bench.ckpt");
    bench("checkpoint save 3MB", 1, 10, || {
        ck.save(&path).unwrap();
    });
    bench("checkpoint load 3MB", 1, 10, || {
        std::hint::black_box(Checkpoint::load(&path).unwrap());
    });
    let _ = std::fs::remove_file(&path);
}
