//! cargo bench target regenerating the paper's table2 on the scaled workload
//! (DESIGN.md §4). Reduced default budget (60 steps/variant); set
//! ROM_STEPS for the full run recorded in EXPERIMENTS.md.
fn main() {
    let rep = rom::experiments::tables::run_experiment("table2", 60)
        .expect("experiment table2 failed (run `make artifacts` first)");
    rep.print();
}
