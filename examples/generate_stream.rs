//! Generation quickstart: train rom-tiny briefly, then decode continuations
//! of two corpus prompts — one at an artifact prefill length (single fused
//! prefill call) and one short prompt (decode_step fallback) — printing the
//! sampled tokens with their corpus topics and the per-token latency.
//!
//!     make artifacts && cargo run --release --example generate_stream

use std::sync::Arc;

use rom::config::TrainCfg;
use rom::coordinator::generate::{generate, GenerateCfg};
use rom::coordinator::trainer::Trainer;
use rom::data::corpus::{Corpus, CorpusSpec};
use rom::experiments::harness::artifacts_root;
use rom::runtime::artifact::Bundle;

fn main() -> anyhow::Result<()> {
    let bundle = Bundle::open(artifacts_root().join("rom-tiny"))?;
    let Some(spec) = bundle.manifest.decode.clone() else {
        anyhow::bail!("rom-tiny has no decode artifacts — re-run `make artifacts`");
    };

    // 1. A short training run so the router and transition tables are live
    //    (the trained session comes straight back from the trainer).
    let cfg = TrainCfg { steps: 40, max_lr: 3e-3, log_every: 0, ..Default::default() };
    let mut trainer = Trainer::new(Arc::clone(&bundle), cfg);
    trainer.quiet = true;
    trainer.final_eval = false;
    let (_report, sess) = trainer.run_session()?;

    // 2. Prompts from held-out corpus streams.
    let corpus = Corpus::new(CorpusSpec::default(), 17);
    let prefill_len = bundle.manifest.eval_lens[0];
    let gen_cfg = GenerateCfg { max_new: 24, temperature: 0.8, top_k: 8, seed: 1 };

    for (label, len) in [("prefill artifact", prefill_len), ("step fallback", 10)] {
        let prompts: Vec<Vec<i32>> =
            (0..spec.batch as u64).map(|r| corpus.generate(7000 + r, len)).collect();
        let report = generate(&sess, &prompts, &gen_cfg)?;
        println!("\n== {label}: {} prompt tokens ==", report.prompt_len);
        for (i, completion) in report.completions.iter().enumerate() {
            let topics: Vec<String> = completion
                .iter()
                .map(|&t| match corpus.topic_of(t) {
                    Some(tp) => tp.to_string(),
                    None => "-".into(), // shared-band token
                })
                .collect();
            println!("row {i} tokens: {completion:?}");
            println!("row {i} topics: [{}]", topics.join(" "));
        }
        println!(
            "prompt consumed in {:.1} ms ({} of {} tokens via prefill artifact)",
            report.prefill_s * 1e3,
            report.prefill_artifact_tokens,
            report.prompt_len
        );
        if let (Some(ms), Some(tps)) =
            (report.median_decode_ms(), report.decode_tokens_per_sec())
        {
            println!("decode: {ms:.2} ms/step median, {tps:.0} tokens/s");
        }
    }
    Ok(())
}
