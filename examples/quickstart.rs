//! Quickstart: load the rom-tiny artifact bundle, take a few training steps
//! on synthetic data, print the loss trajectory and router load.
//!
//!     make artifacts && cargo run --release --example quickstart

use rom::config::TrainCfg;
use rom::coordinator::schedule::CosineSchedule;
use rom::data::corpus::{Corpus, CorpusSpec};
use rom::data::loader::Loader;
use rom::experiments::harness::artifacts_root;
use rom::runtime::artifact::Bundle;
use rom::runtime::session::Session;

fn main() -> anyhow::Result<()> {
    // 1. Open the AOT artifact bundle (on its own PJRT CPU client).
    let bundle = Bundle::open(artifacts_root().join("rom-tiny"))?;
    let man = bundle.manifest.clone();
    println!(
        "loaded {}: {} leaves, {:.2}M total / {:.2}M active params",
        man.name,
        man.num_leaves(),
        man.analysis.total_params as f64 / 1e6,
        man.analysis.active_params as f64 / 1e6
    );

    // 2. Initialize model + optimizer state on device.
    let mut sess = Session::init(std::sync::Arc::clone(&bundle), 0)?;

    // 3. Data pipeline: synthetic topic-Markov corpus -> batched loader.
    let cfg = TrainCfg::default();
    let corpus = Corpus::new(CorpusSpec::default(), 17);
    let steps = 30u64;
    let stream = corpus.generate(
        cfg.data_seed,
        (steps as usize + 2) * man.batch_size * (man.seq_len + 1),
    );
    let mut loader = Loader::new(stream, man.batch_size, man.seq_len, 0);
    let sched = CosineSchedule::new(3e-3, steps, 0.1);

    // 4. Train.
    for step in 1..=steps {
        let batch = loader.next_batch();
        let out = sess.train_step(sched.lr(step) as f32, &batch.tokens, &batch.targets)?;
        if step % 5 == 0 || step == 1 {
            // The Tensor-path train_step always decodes router telemetry.
            let full_load = out.router_load.as_deref().expect("telemetry decoded");
            let load = &full_load[..man.num_experts.min(8)];
            println!(
                "step {step:>3}  loss {:.4}  router0 load {:?}",
                out.loss,
                load.iter().map(|l| (l * 100.0).round() / 100.0).collect::<Vec<_>>()
            );
        }
    }

    // 5. Evaluate perplexity at the shortest context length.
    let ctx = man.eval_lens[0];
    let held = corpus.generate(0xE7A1_0000 + 999, ctx + 1);
    let tokens = rom::runtime::tensor::Tensor::i32(&[1, ctx], held[..ctx].to_vec());
    let targets = rom::runtime::tensor::Tensor::i32(&[1, ctx], held[1..ctx + 1].to_vec());
    let (nll, count) = sess.eval(ctx, &tokens, &targets)?;
    println!("held-out ppl@{ctx}: {:.2}", (nll / count).exp());
    Ok(())
}
