//! Head-to-head example: dense Mamba vs RoM at equal ACTIVE parameters
//! (the paper's headline comparison), trained side by side on the same data
//! with the same budget — literally side by side when ROM_JOBS>1: the two
//! variants fan out across scheduler workers and the rows come back in
//! order, byte-identical to a serial run.
//!
//!     cargo run --release --example compare_arch -- [steps]

use rom::experiments::harness::{runnable_variants, RunSpec};
use rom::experiments::scheduler::{collect_ok, default_jobs, run_sweep};
use rom::substrate::bench::Reporter;

fn main() -> anyhow::Result<()> {
    let steps: u64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(150);

    let mut rep = Reporter::new(
        "dense Mamba vs RoM (equal active params, equal budget)",
        &["variant", "active", "total", "loss", "ppl@128", "ppl@512"],
    );
    // Same skip semantics as `rom experiment` (missing artifacts warn,
    // ROM_VARIANT_FILTER honored).
    let variants = runnable_variants(&["mamba-tiny", "rom-tiny"]);
    let spec = RunSpec::new(steps, 3e-3);
    let results = run_sweep(&variants, &spec, default_jobs());
    let (rows, failed) = collect_ok(&variants, results);
    for (_name, r) in rows {
        rep.row(&[
            r.name.clone(),
            format!("{:.2}M", r.active_params as f64 / 1e6),
            format!("{:.2}M", r.total_params as f64 / 1e6),
            format!("{:.3}", r.smoothed_loss),
            r.ppl_at(128).map(|p| format!("{p:.2}")).unwrap_or_else(|| "-".into()),
            r.ppl_at(512).map(|p| format!("{p:.2}")).unwrap_or_else(|| "-".into()),
        ]);
    }
    rep.print();
    if failed > 0 {
        anyhow::bail!("{failed} variant(s) failed — see warnings above");
    }
    println!("expected shape (paper Fig 3): RoM reaches lower PPL than dense");
    println!("Mamba at the same active-parameter count.");
    Ok(())
}
