//! Head-to-head example: dense Mamba vs RoM at equal ACTIVE parameters
//! (the paper's headline comparison), trained side by side on the same data
//! with the same budget.
//!
//!     cargo run --release --example compare_arch -- [steps]

use rom::experiments::harness::{artifacts_root, run_variant};
use rom::substrate::bench::Reporter;

fn main() -> anyhow::Result<()> {
    let steps: u64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(150);

    let mut rep = Reporter::new(
        "dense Mamba vs RoM (equal active params, equal budget)",
        &["variant", "active", "total", "loss", "ppl@128", "ppl@512"],
    );
    for name in ["mamba-tiny", "rom-tiny"] {
        if !artifacts_root().join(name).exists() {
            eprintln!("missing artifacts for {name}; run `make artifacts`");
            continue;
        }
        let r = run_variant(name, steps, 3e-3)?;
        rep.row(&[
            r.name.clone(),
            format!("{:.2}M", r.active_params as f64 / 1e6),
            format!("{:.2}M", r.total_params as f64 / 1e6),
            format!("{:.3}", r.smoothed_loss),
            r.ppl_at(128).map(|p| format!("{p:.2}")).unwrap_or("-".into()),
            r.ppl_at(512).map(|p| format!("{p:.2}")).unwrap_or("-".into()),
        ]);
    }
    rep.print();
    println!("expected shape (paper Fig 3): RoM reaches lower PPL than dense");
    println!("Mamba at the same active-parameter count.");
    Ok(())
}
