//! Length-extrapolation example (Figure 4 in miniature): train briefly, then
//! sweep held-out perplexity across context lengths longer than the training
//! sequence, demonstrating the consistent-PPL property of RoM/Mamba models.
//!
//!     cargo run --release --example eval_lengths -- [variant] [steps]

use rom::config::TrainCfg;
use rom::coordinator::trainer::Trainer;
use rom::experiments::harness::artifacts_root;
use rom::runtime::artifact::Bundle;

fn main() -> anyhow::Result<()> {
    let variant = std::env::args().nth(1).unwrap_or_else(|| "rom-tiny".into());
    let steps: u64 = std::env::args()
        .nth(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(120);

    let bundle = Bundle::open(artifacts_root().join(&variant))?;
    println!(
        "{}: trained at T={}, evaluating at {:?}",
        variant, bundle.manifest.seq_len, bundle.manifest.eval_lens
    );
    let cfg = TrainCfg { steps, max_lr: 3e-3, log_every: (steps / 4).max(1), ..Default::default() };
    let trainer = Trainer::new(std::sync::Arc::clone(&bundle), cfg);
    let report = trainer.run()?;

    println!("\nctx_len  ppl      (train T = {})", bundle.manifest.seq_len);
    for (ctx, ppl) in &report.eval_ppl {
        let marker = if *ctx > bundle.manifest.seq_len { " <- extrapolation" } else { "" };
        println!("{ctx:>7}  {ppl:<8.3}{marker}");
    }
    Ok(())
}
