//! END-TO-END DRIVER (DESIGN.md §2, deliverable (b)): train the rom-e2e
//! model — whose artifacts lower the *Pallas* selective-scan and short-conv
//! kernels into the HLO hot path — for several hundred steps on the synthetic
//! corpus, logging the loss curve, expert load balance, throughput, and the
//! final multi-length perplexity sweep. Proves all three layers compose:
//! Pallas kernel (L1) -> jax model AOT (L2) -> rust coordinator (L3).
//!
//!     make artifacts && cargo run --release --example train_rom -- [steps]
//!
//! The run recorded in EXPERIMENTS.md §E2E used the default 300 steps.

use rom::config::TrainCfg;
use rom::coordinator::trainer::Trainer;
use rom::experiments::harness::artifacts_root;
use rom::runtime::artifact::Bundle;

fn main() -> anyhow::Result<()> {
    let steps: u64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);

    // rom-e2e = 4-layer Mamba + RoM(conv,gate,out; 8 experts top-1), with
    // scan_impl="pallas": the L1 kernels are in this artifact's HLO.
    let bundle = Bundle::open(artifacts_root().join("rom-e2e"))?;
    println!(
        "e2e model: {} ({:.2}M total / {:.2}M active, pallas hot path)",
        bundle.manifest.name,
        bundle.manifest.analysis.total_params as f64 / 1e6,
        bundle.manifest.analysis.active_params as f64 / 1e6,
    );

    let cfg = TrainCfg {
        steps,
        max_lr: 3e-3,
        warmup_ratio: 0.03,
        eval_every: (steps / 3).max(1),
        log_every: (steps / 20).max(1),
        ..TrainCfg::default()
    };
    let mut trainer = Trainer::new(std::sync::Arc::clone(&bundle), cfg);
    trainer.checkpoint_dir = Some("checkpoints".into());
    let report = trainer.run()?;

    println!("\n=== e2e summary ===");
    println!("steps:          {steps}");
    println!("final loss:     {:.4}", report.final_loss);
    println!("smoothed loss:  {:.4}", report.smoothed_loss);
    println!("throughput:     {:.0} tokens/s", report.tokens_per_sec);
    for (ctx, ppl) in &report.eval_ppl {
        println!("ppl@{ctx}:        {ppl:.3}");
    }
    println!(
        "expert balance: max/uniform {:.2} (1.0 = perfect), entropy {:.3}",
        report.balance.max_over_uniform, report.balance.norm_entropy
    );
    report.metrics.save(std::path::Path::new("e2e_metrics.json"))?;
    println!("loss curve written to e2e_metrics.json");
    Ok(())
}
